"""AsyncEchoEngine: the real-time continuous-batching front door.

This is the production path ROADMAP item 1 asks for: the same
``EchoService``/``EngineBackend`` stack the trace benchmarks drive, but
run by a live asyncio loop instead of a replay driver. One background
task owns the backend:

  * ``engine.step`` runs off-thread (``asyncio.to_thread``) so thousands
    of connections keep streaming while an iteration computes — the vLLM
    ``LLMEngine``-wrapper idiom;
  * arrivals are stamped with *real* times at the front door, so
    ``AdmissionController`` verdicts (bounded queue, SLO-feasibility
    shed) judge live load, not trace timestamps;
  * token/finish/abort/shed events emitted by the step (on the worker
    thread, serialized by the ``EventBus`` lock) are queued and dispatched
    to per-request ``asyncio.Queue``s on the loop thread — tokens stream
    to ``AsyncRequestHandle`` consumers as they land;
  * backpressure is explicit at both ends: a bounded submit queue
    (saturation sheds — or blocks, the caller's choice) and a per-request
    token-queue cap that aborts slow consumers instead of buffering
    unboundedly;
  * ``drain()`` is the graceful shutdown: stop admitting, finish (or,
    past a deadline, shed) in-flight work, flush the swap stager, land
    every in-flight KV transfer, stop.

The wall clock and the backend clock meet here for the first time: the
scheduler's ``TimeModel`` estimates gate the admission of live requests,
so estimator fidelity becomes a user-visible SLO property. With a
``ManualClock`` the serving domain is paused and the loop replays traces
bit-identically to ``EchoService.drive`` (the equivalence tests).
"""
from __future__ import annotations

import asyncio
import enum
import logging
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Sequence, Union

from repro.core.request import Request, TaskType
from repro.serving.handle import HandleStatus
from repro.serving.service import EchoService
from repro.rt.clock import ManualClock, WallClock
from repro.rt.handle import AsyncRequestHandle, SubmitQueueFull

logger = logging.getLogger(__name__)


class RTState(enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    DRAINING = "draining"
    STOPPED = "stopped"


@dataclass
class RTStats:
    """Front-door accounting, disjoint from the backend's EngineStats."""
    submitted: int = 0
    finished: int = 0
    aborted: int = 0
    shed: int = 0                      # all terminal SHED handles
    shed_submit_queue: int = 0         # bounded submit queue saturated
    shed_closed: int = 0               # submitted while draining/stopped
    slow_consumer_aborts: int = 0      # token-queue cap hit
    drain_sheds: int = 0               # in-flight work shed at drain
    preemptions: int = 0
    steps: int = 0                     # backend iterations driven
    hops: int = 0                      # to_thread round trips
    peak_live: int = 0


class AsyncEchoEngine:
    """Asyncio front door over an ``EchoService`` (or anything
    ``make_backend`` accepts: ``EchoEngine``, ``ClusterSimulator``).

    Lifecycle::

        rt = AsyncEchoEngine(engine, admission=AdmissionConfig(...))
        async with rt:                       # start() ... drain()
            h = await rt.submit(prompt, task_type="online",
                                max_new_tokens=16, slo=SLO(1.0, 0.1))
            async for ev in h.tokens():
                ...
            await h.abort()                  # or cancel mid-stream

    ``steps_per_hop`` batches backend iterations per worker-thread round
    trip (throughput knob; 1 = lowest streaming latency). ``pace=True``
    throttles the loop so the backend's virtual clock never runs ahead of
    the wall clock — a real-time simulation of the modeled hardware.
    """

    def __init__(self, backend, *,
                 admission=None,
                 clock: Optional[Union[WallClock, ManualClock]] = None,
                 max_submit_queue: int = 4096,
                 token_queue_cap: int = 1024,
                 steps_per_hop: int = 1,
                 pace: bool = False):
        self.service = (backend if isinstance(backend, EchoService)
                        else EchoService(backend, admission=admission))
        self.clock = clock if clock is not None else WallClock()
        self.token_queue_cap = token_queue_cap
        self.steps_per_hop = max(steps_per_hop, 1)
        self.pace = pace
        self.stats = RTStats()
        self._state = RTState.CREATED
        self._task: Optional[asyncio.Task] = None
        self._intake: asyncio.Queue = asyncio.Queue(maxsize=max_submit_queue)
        self._wake = asyncio.Event()
        self._live: Dict[int, AsyncRequestHandle] = {}
        self._control: Deque = deque()     # ("abort", handle, future|None)
        self._events: Deque = deque()      # bus events awaiting dispatch
        self._shed_requested = False
        self._last_arrival = 0.0           # monotone live-arrival stamps
        self._done_cbs: List[Callable[[AsyncRequestHandle], None]] = []
        bus = self.service.events
        # bridge: bus callbacks fire on whichever thread emitted (the step
        # worker, mostly); they only append — the loop thread dispatches
        bus.on_token(lambda ev: self._events.append(("token", ev)))
        bus.on_finish(lambda h: self._events.append(("finish", h)))
        bus.on_abort(lambda h: self._events.append(("abort", h)))
        bus.on_shed(lambda h: self._events.append(("shed", h)))
        bus.on_preempt(lambda h: self._events.append(("preempt", h)))

    # ------------------------------------------------------------- sugar
    @property
    def state(self) -> RTState:
        return self._state

    @property
    def engine(self):
        return self.service.engine

    @property
    def live(self):
        """The service's event-driven LiveMetrics (backend-clock domain)."""
        return self.service.live

    @property
    def events(self):
        return self.service.events

    def live_requests(self) -> int:
        """Handles between submit and terminal (intake queue included)."""
        return len(self._live) + self._intake.qsize()

    def on_request_done(self, cb: Callable[[AsyncRequestHandle], None]):
        """Register a loop-thread callback fired at every handle's terminal
        transition (the RTProbe's hook for wall-clock histograms/spans)."""
        self._done_cbs.append(cb)
        return cb

    # ------------------------------------------------------------- intake
    async def submit(self, prompt: Sequence[int], *,
                     task_type: Union[TaskType, str] = TaskType.ONLINE,
                     max_new_tokens: int = 16,
                     slo=None,
                     arrival_time: Optional[float] = None,
                     wait: bool = True) -> AsyncRequestHandle:
        """Build and submit one request; returns its async handle.

        ``arrival_time`` defaults to live stamping: the request arrives
        "now" in the backend's clock domain when the loop picks it up (the
        wall-clock admission path). Pass an explicit time to replay a
        trace. With ``wait`` the call backpressures (awaits a submit-queue
        slot); without it a saturated queue sheds immediately."""
        if isinstance(task_type, str):
            task_type = TaskType(task_type)
        req = Request(prompt=tuple(prompt), max_new_tokens=max_new_tokens,
                      task_type=task_type,
                      arrival_time=(0.0 if arrival_time is None
                                    else arrival_time),
                      slo=slo)
        return await self.submit_request(
            req, live_arrival=arrival_time is None, wait=wait)

    async def submit_request(self, req: Request, *,
                             live_arrival: bool = False,
                             wait: bool = True) -> AsyncRequestHandle:
        """Submit a pre-built ``Request`` (trace replay keeps its
        ``arrival_time``; ``live_arrival`` stamps it at intake)."""
        handle = AsyncRequestHandle(self, req,
                                    token_queue_cap=self.token_queue_cap,
                                    live_arrival=live_arrival)
        self.stats.submitted += 1
        if self._state in (RTState.DRAINING, RTState.STOPPED):
            self.stats.shed_closed += 1
            self._finalize_handle(handle, HandleStatus.SHED)
            return handle
        if wait:
            await self._intake.put(handle)
        else:
            try:
                self._intake.put_nowait(handle)
            except asyncio.QueueFull:
                self.stats.shed_submit_queue += 1
                self._finalize_handle(handle, HandleStatus.SHED)
                return handle
        self.stats.peak_live = max(self.stats.peak_live,
                                   self.live_requests())
        self._wake.set()
        return handle

    def try_submit_nowait(self, req: Request, *,
                          live_arrival: bool = True) -> AsyncRequestHandle:
        """Synchronous non-blocking submit for callers already on the loop
        thread; raises ``SubmitQueueFull`` when saturated."""
        handle = AsyncRequestHandle(self, req,
                                    token_queue_cap=self.token_queue_cap,
                                    live_arrival=live_arrival)
        self.stats.submitted += 1
        if self._state in (RTState.DRAINING, RTState.STOPPED):
            self.stats.shed_closed += 1
            self._finalize_handle(handle, HandleStatus.SHED)
            return handle
        try:
            self._intake.put_nowait(handle)
        except asyncio.QueueFull:
            self.stats.shed_submit_queue += 1
            raise SubmitQueueFull(
                f"submit queue full ({self._intake.maxsize})") from None
        self._wake.set()
        return handle

    # ------------------------------------------------------------- control
    async def _abort(self, handle: AsyncRequestHandle) -> bool:
        if handle.done:
            return False
        fut = asyncio.get_running_loop().create_future()
        self._control.append(("abort", handle, fut))
        self._wake.set()
        if self._task is None:          # loop not running: resolve inline
            self._process_control()
            self._dispatch()
        return await fut

    # ------------------------------------------------------------- lifecycle
    async def start(self) -> "AsyncEchoEngine":
        if self._task is not None:
            raise RuntimeError("AsyncEchoEngine already started")
        self._state = RTState.RUNNING
        self._task = asyncio.create_task(self._run(), name="echo-rt-loop")
        return self

    async def drain(self, *, shed_after: Optional[float] = None) -> None:
        """Graceful shutdown: stop admitting (new submits are shed), let
        in-flight work finish, flush the swap stager, stop the loop. With
        ``shed_after`` (wall seconds) still-unfinished work is shed once
        the deadline passes instead of waiting forever."""
        if self._task is None:
            self._state = RTState.STOPPED
            return
        if self._state is RTState.RUNNING:
            self._state = RTState.DRAINING
        self._wake.set()
        if shed_after is not None:
            done, _ = await asyncio.wait({self._task}, timeout=shed_after)
            if not done:
                self._shed_requested = True
                self._wake.set()
        await self._task

    async def stop(self) -> None:
        """Hard stop: shed/abort all in-flight work, then drain."""
        self._shed_requested = True
        await self.drain()

    async def __aenter__(self) -> "AsyncEchoEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.drain()

    # ------------------------------------------------------------- loop
    async def _run(self) -> None:
        try:
            while True:
                self._drain_intake()
                self._process_control()
                self._dispatch()
                if self._shed_requested:
                    self._shed_requested = False
                    self._shed_live()
                    self._dispatch()
                if self._state is RTState.DRAINING and self._drained():
                    break
                progressed = False
                if self._backend_busy():
                    progressed = await asyncio.to_thread(self._step_hop)
                    self.stats.hops += 1
                    self._dispatch()
                if progressed:
                    if self.pace:
                        lag = self.service.now - self.clock.now()
                        if lag > 1e-4:
                            await asyncio.sleep(min(lag, 0.25))
                    continue
                if self._state is RTState.DRAINING:
                    if self._drained():
                        break
                    if self._intake.empty() and not self._control:
                        if self._live:
                            # backend can make no more progress but live
                            # requests remain (unschedulable backlog):
                            # shed them so drain terminates
                            self._shed_live()
                            self._dispatch()
                        else:
                            logger.warning(
                                "drain: backend still busy with foreign "
                                "work and no live handles; stopping")
                            break
                    continue
                # idle: sleep until a submit / abort / drain wakes us
                self._wake.clear()
                if (self._intake.empty() and not self._control
                        and not self._events
                        and self._state is RTState.RUNNING
                        and not self._backend_busy()):
                    await self._wake.wait()
        finally:
            backend = self.service.backend
            if hasattr(backend, "flush"):
                backend.flush()        # land in-flight swap staging
            self._dispatch()
            self._state = RTState.STOPPED

    # ------------------------------------------------- loop-thread internals
    def _drain_intake(self) -> None:
        while True:
            try:
                handle = self._intake.get_nowait()
            except asyncio.QueueEmpty:
                return
            if handle.done:             # cancelled while still queued
                continue
            req = handle.request
            if handle.live_arrival:
                # wall-clock admission: the request arrives *now* in the
                # backend's clock domain — the verdict judges live load
                self._last_arrival = max(self.service.now,
                                         self._last_arrival)
                req.arrival_time = self._last_arrival
            # register before submitting: a synchronous shed verdict emits
            # through the bus and must find the handle at dispatch
            self._live[req.rid] = handle
            self.stats.peak_live = max(self.stats.peak_live,
                                       self.live_requests())
            handle._sync = self.service.submit_request(req)

    def _process_control(self) -> None:
        while self._control:
            _, handle, fut = self._control.popleft()
            ok = False
            if not handle.done:
                if handle._sync is None:
                    # never drained from intake: terminal right here
                    handle._cancelled = True
                    self._finalize_handle(handle, HandleStatus.ABORTED)
                    ok = True
                else:
                    ok = self.service.abort(handle._sync)
            if fut is not None and not fut.done():
                fut.set_result(ok)

    def _step_hop(self) -> bool:
        """Worker thread: up to ``steps_per_hop`` backend events."""
        progressed = False
        for _ in range(self.steps_per_hop):
            if not self.service.step():
                break
            progressed = True
            self.stats.steps += 1
        return progressed

    def _dispatch(self) -> None:
        now_wall = self.clock.now()
        while self._events:
            kind, payload = self._events.popleft()
            if kind == "token":
                handle = self._live.get(payload.handle.rid)
                if handle is None:
                    continue            # foreign request or already closed
                if not handle._push_token(payload.token, payload.index,
                                          payload.t, now_wall):
                    # slow consumer: the bounded token queue is full —
                    # abort instead of buffering unboundedly
                    self.stats.slow_consumer_aborts += 1
                    self._control.append(("abort", handle, None))
                    self._wake.set()
            elif kind == "preempt":
                self.stats.preemptions += 1
            else:                       # finish / abort / shed
                handle = self._live.get(payload.rid)
                if handle is None:
                    continue
                status = {"finish": HandleStatus.FINISHED,
                          "abort": HandleStatus.ABORTED,
                          "shed": HandleStatus.SHED}[kind]
                self._finalize_handle(handle, status)

    def _finalize_handle(self, handle: AsyncRequestHandle,
                         status: HandleStatus) -> None:
        if handle._closed is not None:
            return
        self._live.pop(handle.rid, None)
        handle._finalize(status, self.clock.now())
        if status is HandleStatus.FINISHED:
            self.stats.finished += 1
        elif status is HandleStatus.ABORTED:
            self.stats.aborted += 1
        elif status is HandleStatus.SHED:
            self.stats.shed += 1
        for cb in self._done_cbs:
            try:
                cb(handle)
            except Exception:
                logger.warning("on_request_done callback %r raised", cb,
                               exc_info=True)

    def _shed_live(self) -> None:
        for handle in list(self._live.values()):
            if handle.done:
                continue
            if handle._sync is not None:
                if self.service.abort(handle._sync):
                    self.stats.drain_sheds += 1
            else:
                handle._cancelled = True
                self._finalize_handle(handle, HandleStatus.ABORTED)
                self.stats.drain_sheds += 1

    def _backend_busy(self) -> bool:
        return (self.service.backend.has_work()
                or self.service.pending_frontdoor() > 0)

    def _drained(self) -> bool:
        return (self._intake.empty() and not self._control
                and not self._events and not self._live
                and not self._backend_busy())

    # ------------------------------------------------------------- checks
    def kv_leaks(self) -> Dict[str, int]:
        """Post-drain invariant probe: everything here must be zero after a
        graceful drain — request-owned device blocks, outstanding
        unfinished-owner pins on either tier, in-flight stager transfers,
        scheduler running entries, and live handles."""
        leaks = {"request_owned_blocks": 0, "device_owner_pins": 0,
                 "host_owner_pins": 0, "inflight_transfers": 0,
                 "scheduler_running": 0,
                 "live_handles": len(self._live) + self._intake.qsize()}
        for eng in self.service.backend.engines():
            leaks["request_owned_blocks"] += eng.bm.running_blocks
            leaks["device_owner_pins"] += sum(
                b.unfinished_owners for b in eng.bm.blocks)
            if eng.bm.host is not None:
                leaks["host_owner_pins"] += sum(
                    hb.unfinished_owners
                    for hb in eng.bm.host.blocks.values())
            if eng._stager is not None:
                leaks["inflight_transfers"] += eng._stager.inflight_blocks()
            leaks["scheduler_running"] += len(eng.scheduler.running)
        return leaks

    # ------------------------------------------------------------- obs
    def instrument(self, registry=None, tracer=None):
        """Attach the observability layer: the service-level bridge plus
        the RT probe's wall-clock TTFT/TPOT histograms and per-connection
        tracer spans. Returns the registry."""
        from repro.obs import MetricsRegistry
        from repro.obs.probes import instrument_rt
        if registry is None:
            registry = MetricsRegistry()
        self.service.instrument(registry, tracer)
        instrument_rt(self, registry, tracer)
        return registry


# re-exported for convenience alongside the engine
__all__ = ["AsyncEchoEngine", "RTState", "RTStats"]
