"""Newline-delimited-JSON TCP front end over ``AsyncEchoEngine``.

Stdlib-only (``asyncio.start_server``), so ``repro serve --serve`` listens
without pulling in an HTTP framework. Protocol, one JSON object per line:

  client -> server   {"prompt": [1, 2, 3], "max_new_tokens": 16,
                      "task_type": "online", "slo": [1.0, 0.1]}
  server -> client   {"token": 17, "index": 0, "t_wall": 0.012}   (streamed)
                     ...
                     {"done": true, "status": "finished",
                      "n_tokens": 16, "ttft_wall": 0.012,
                      "tpot_wall": 0.003}                         (terminal)

A malformed request line answers ``{"error": ...}`` and keeps the
connection; a client disconnect mid-stream aborts its in-flight request so
the engine releases KV blocks immediately. Each connection handles one
request at a time (pipeline by sending the next line after the ``done``).
"""
from __future__ import annotations

import asyncio
import json
import logging
from typing import Optional

from repro.core.request import SLO, TaskType
from repro.rt.engine_loop import AsyncEchoEngine

logger = logging.getLogger(__name__)


def _parse_request(line: bytes) -> dict:
    obj = json.loads(line)
    if not isinstance(obj, dict) or "prompt" not in obj:
        raise ValueError("request must be a JSON object with a 'prompt'")
    prompt = obj["prompt"]
    if not isinstance(prompt, list) or not prompt \
            or not all(isinstance(t, int) for t in prompt):
        raise ValueError("'prompt' must be a non-empty list of ints")
    kwargs = {
        "task_type": TaskType(obj.get("task_type", "online")),
        "max_new_tokens": int(obj.get("max_new_tokens", 16)),
    }
    slo = obj.get("slo")
    if slo is not None:
        kwargs["slo"] = SLO(ttft=float(slo[0]), tpot=float(slo[1]))
    return {"prompt": prompt, **kwargs}


class EchoServer:
    """One listening socket bound to one ``AsyncEchoEngine``."""

    def __init__(self, rt: AsyncEchoEngine, *, host: str = "127.0.0.1",
                 port: int = 8631):
        self.rt = rt
        self.host = host
        self.port = port
        self._server: Optional[asyncio.base_events.Server] = None
        self.connections = 0
        self.requests_served = 0

    async def start(self) -> "EchoServer":
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        return self

    @property
    def address(self):
        sock = self._server.sockets[0]
        return sock.getsockname()[:2]

    async def close(self) -> None:
        """Stop accepting, then gracefully drain the engine."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        await self.rt.drain()

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    # ------------------------------------------------------------ per-conn
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self.connections += 1
        handle = None
        try:
            while True:
                line = await reader.readline()
                if not line:           # EOF: client went away
                    break
                line = line.strip()
                if not line:
                    continue
                try:
                    spec = _parse_request(line)
                except (ValueError, KeyError, TypeError,
                        json.JSONDecodeError) as exc:
                    writer.write(json.dumps(
                        {"error": str(exc)}).encode() + b"\n")
                    await writer.drain()
                    continue
                handle = await self.rt.submit(**spec)
                async for ev in handle.tokens():
                    writer.write(json.dumps(
                        {"token": ev.token, "index": ev.index,
                         "t_wall": round(ev.t_wall, 6)}).encode() + b"\n")
                    await writer.drain()
                result = await handle.result()
                writer.write(json.dumps(
                    {"done": True, "status": result.status.value,
                     "n_tokens": len(result.tokens),
                     "ttft_wall": handle.wall_ttft(),
                     "tpot_wall": handle.wall_tpot()}).encode() + b"\n")
                await writer.drain()
                self.requests_served += 1
                handle = None
        except (ConnectionResetError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            # disconnect mid-stream: release the in-flight request's KV now
            if handle is not None and not handle.done:
                try:
                    await handle.abort()
                except Exception:
                    logger.warning("abort on disconnect failed",
                                   exc_info=True)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass


async def request_once(host: str, port: int, prompt, *,
                       max_new_tokens: int = 16, task_type: str = "online",
                       slo=None) -> dict:
    """Minimal client: one request, collect the stream, return the summary
    dict (with ``tokens`` added). Used by the examples and smoke tests."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        spec = {"prompt": list(prompt), "max_new_tokens": max_new_tokens,
                "task_type": task_type}
        if slo is not None:
            spec["slo"] = [slo.ttft, slo.tpot]
        writer.write(json.dumps(spec).encode() + b"\n")
        await writer.drain()
        tokens = []
        while True:
            line = await reader.readline()
            if not line:
                raise ConnectionError("server closed mid-stream")
            obj = json.loads(line)
            if "error" in obj:
                raise ValueError(obj["error"])
            if obj.get("done"):
                obj["tokens"] = tokens
                return obj
            tokens.append(obj["token"])
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
