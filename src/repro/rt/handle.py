"""Async request handles: one live connection's view of its request.

``AsyncEchoEngine.submit`` returns an ``AsyncRequestHandle``. The caller
streams tokens with ``async for ev in handle.tokens()``, awaits the
terminal summary with ``await handle.result()``, and cancels mid-flight
with ``await handle.abort()``. Unlike the synchronous
``serving.RequestHandle`` — whose ``tokens()`` generator *drives* the
backend — this handle is passive: the engine's continuous-batching loop
pushes token events into a bounded per-request queue and the consumer
just awaits them, so thousands of connections stream concurrently off one
loop.

Every handle carries stamps in both time domains: the backend's clock
(``t_engine`` on each token, the engine-side TTFT/TPOT in ``result()``)
and the serving clock (``t_wall``, ``wall_ttft()``, ``wall_tpot()``) —
the latter is what a real client measures against its SLO.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import TYPE_CHECKING, AsyncIterator, Optional

from repro.core.request import Request
from repro.serving.handle import HandleStatus, RequestResult

if TYPE_CHECKING:                      # avoid a runtime import cycle
    from repro.rt.engine_loop import AsyncEchoEngine

_EOS = object()                        # closes the token stream


class SubmitQueueFull(RuntimeError):
    """Raised by ``submit(..., wait=False)`` when the bounded submit queue
    is saturated and the engine is configured to raise instead of shed."""


@dataclass(frozen=True)
class AsyncTokenEvent:
    """One streamed token, stamped in both time domains."""
    token: int
    index: int                 # 0-based output position
    t_engine: float            # backend clock at emission (iteration end)
    t_wall: float              # serving clock when the loop delivered it

    @property
    def first(self) -> bool:
        return self.index == 0


class AsyncRequestHandle:
    """Live view of one request inside an ``AsyncEchoEngine``."""

    def __init__(self, engine: "AsyncEchoEngine", request: Request, *,
                 token_queue_cap: int = 0, live_arrival: bool = True):
        self._engine = engine
        self.request = request
        self.live_arrival = live_arrival   # stamp arrival at intake drain
        self._queue: asyncio.Queue = asyncio.Queue(maxsize=token_queue_cap)
        self._done: asyncio.Future = \
            asyncio.get_running_loop().create_future()
        self._sync = None                  # serving handle once admitted
        self._closed: Optional[HandleStatus] = None   # set at finalize
        self._cancelled = False            # aborted while still in intake
        self.overflowed = False            # slow consumer: queue cap hit
        # wall-domain stamps (serving clock)
        self.t_submit_wall: float = engine.clock.now()
        self.t_first_token_wall: Optional[float] = None
        self.t_last_token_wall: Optional[float] = None
        self.t_finish_wall: Optional[float] = None
        self.n_tokens = 0                  # tokens pushed (streamed or not)

    # ------------------------------------------------------------- identity
    @property
    def rid(self) -> int:
        return self.request.rid

    def __repr__(self) -> str:
        return (f"AsyncRequestHandle(rid={self.rid}, "
                f"status={self.status.value}, tokens={self.n_tokens})")

    # ------------------------------------------------------------- status
    @property
    def status(self) -> HandleStatus:
        if self._closed is not None:
            return self._closed
        if self._cancelled:
            return HandleStatus.ABORTED
        if self._sync is not None:
            return self._sync.status
        return HandleStatus.QUEUED         # still in the intake queue

    @property
    def done(self) -> bool:
        return self._closed is not None or self._cancelled

    # ------------------------------------------------------------- metrics
    def wall_ttft(self) -> Optional[float]:
        """Serving-clock time from submit to first streamed token."""
        if self.t_first_token_wall is None:
            return None
        return self.t_first_token_wall - self.t_submit_wall

    def wall_tpot(self) -> Optional[float]:
        """Serving-clock seconds per output token after the first."""
        if self.t_last_token_wall is None or self.n_tokens < 2:
            return None
        return ((self.t_last_token_wall - self.t_first_token_wall)
                / (self.n_tokens - 1))

    def wall_latency(self) -> Optional[float]:
        """Submit-to-terminal serving-clock latency."""
        if self.t_finish_wall is None:
            return None
        return self.t_finish_wall - self.t_submit_wall

    # ------------------------------------------------------------- stream
    async def tokens(self) -> AsyncIterator[AsyncTokenEvent]:
        """Stream token events as the engine loop produces them. Ends when
        the request reaches a terminal state (finished, aborted, or shed —
        check ``status`` afterwards to tell which)."""
        while True:
            item = await self._queue.get()
            if item is _EOS:
                return
            yield item

    # ------------------------------------------------------------- result
    async def result(self) -> RequestResult:
        """Await the terminal summary (engine-domain ttft/tpot; the wall
        numbers live on the handle). Never raises on cancellation: an
        aborted/shed request reports partial tokens with its status."""
        return await asyncio.shield(self._done)

    # ------------------------------------------------------------- control
    async def abort(self) -> bool:
        """Cancel mid-flight: the loop frees KV blocks, drops radix-pool
        pins, and removes the request from scheduler queues. Returns False
        if the request was already terminal."""
        return await self._engine._abort(self)

    # --------------------------------------------------- loop-thread side
    # (the methods below run on the event-loop thread only)
    def _push_token(self, token: int, index: int, t_engine: float,
                    t_wall: float) -> bool:
        """Queue one token for the consumer. Returns False when the bounded
        queue is full — the slow-consumer signal the engine turns into an
        abort."""
        ev = AsyncTokenEvent(token=token, index=index,
                             t_engine=t_engine, t_wall=t_wall)
        try:
            self._queue.put_nowait(ev)
        except asyncio.QueueFull:
            self.overflowed = True
            return False
        if self.t_first_token_wall is None:
            self.t_first_token_wall = t_wall
        self.t_last_token_wall = t_wall
        self.n_tokens += 1
        return True

    def _finalize(self, status: HandleStatus, t_wall: float) -> None:
        """Terminal transition: close the stream and resolve ``result()``.
        Idempotent — the first status wins."""
        if self._closed is not None:
            return
        self._closed = status
        self.t_finish_wall = t_wall
        try:
            self._queue.put_nowait(_EOS)
        except asyncio.QueueFull:
            # slow consumer raced the close: drop the oldest undelivered
            # token so the EOS always lands and the stream terminates
            try:
                self._queue.get_nowait()
            except asyncio.QueueEmpty:
                pass
            self._queue.put_nowait(_EOS)
        if not self._done.done():
            req = self.request
            self._done.set_result(RequestResult(
                tokens=list(req.output_tokens), status=status,
                ttft=req.ttft(), tpot=req.tpot(),
                finish_time=req.finish_time,
                n_preemptions=req.n_preemptions))
