"""Cold-start PCIe link calibration: measure, fit, then serve.

The swap terms of the ``TimeModel`` (``swap_byte``/``swap_floor``/
``swap_launch``) price every swap-vs-recompute decision and every SLO
charge for carried transfer traffic — but the presets are nominal link
numbers (PCIe 4.0/5.0 x16). A server should not price a link it never
measured: at startup, ``serve --serve`` runs a few real
``jax.device_put``/``device_get`` round trips, fits the byte rate and
dispatch floor with ``TimeModel.fit_swap``, and (optionally) overlaps a
transfer with a jitted matmul to recover the async-copy launch overhead
via ``TimeModel.fit_swap_overlap`` — all before the first request is
admitted.

Everything degrades gracefully: no jax, a CPU-only platform where
"device" transfers are memcpys, or a degenerate fit (zero byte rate)
leaves the preset terms untouched and reports why.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

logger = logging.getLogger(__name__)

# modest payloads: enough spread for a 2-term lstsq, small enough that
# startup stays sub-second even over a slow link
DEFAULT_SIZES = (1 << 18, 1 << 20, 1 << 22)      # 256 KiB, 1 MiB, 4 MiB


@dataclass
class LinkCalibration:
    """Outcome of one cold-start calibration run."""
    applied: bool                      # did the fit replace the presets?
    backend: str                       # jax platform name, or "unavailable"
    swap_byte: float                   # the model's terms after the run
    swap_floor: float
    swap_launch: float
    samples: List[Tuple[int, float]] = field(default_factory=list)
    overlap_samples: List[Tuple[float, int, float]] = \
        field(default_factory=list)
    error: Optional[str] = None

    @property
    def bandwidth_gbs(self) -> Optional[float]:
        """Fitted effective link bandwidth, GB/s."""
        if self.swap_byte <= 0.0:
            return None
        return 1.0 / (self.swap_byte * 1e9)

    def summary(self) -> str:
        if not self.applied:
            return (f"link calibration skipped ({self.error}); "
                    f"keeping preset swap terms")
        bw = self.bandwidth_gbs
        return (f"link calibrated on {self.backend}: "
                f"{bw:.1f} GB/s effective, floor {self.swap_floor*1e6:.0f}us, "
                f"launch {self.swap_launch*1e6:.0f}us "
                f"({len(self.samples)} transfer samples)")


def _import_jax():
    try:
        import jax
        import jax.numpy as jnp
        return jax, jnp
    except Exception:                  # ImportError or broken install
        return None, None


def measure_link(sizes=DEFAULT_SIZES,
                 repeats: int = 3) -> Optional[List[Tuple[int, float]]]:
    """Time real host->device and device->host transfers. Returns
    ``(n_bytes, seconds)`` samples (both directions pooled — the fit
    recovers one effective link rate), or None without jax."""
    jax, _ = _import_jax()
    if jax is None:
        return None
    import numpy as np
    samples: List[Tuple[int, float]] = []
    for n in sizes:
        buf = np.zeros(n, dtype=np.uint8)
        # one unmeasured round trip per size: allocator/compile warm-up
        dev = jax.block_until_ready(jax.device_put(buf))
        jax.device_get(dev)
        for _ in range(repeats):
            t0 = time.perf_counter()
            dev = jax.block_until_ready(jax.device_put(buf))
            samples.append((n, time.perf_counter() - t0))
            t0 = time.perf_counter()
            jax.device_get(dev)
            samples.append((n, time.perf_counter() - t0))
    return samples


def measure_overlap(tm, sizes=DEFAULT_SIZES, repeats: int = 2,
                    matmul_dim: int = 512) -> List[Tuple[float, int, float]]:
    """Overlap a ``device_put`` (issued from a helper thread) with a jitted
    matmul and time the pair — ``(compute_s, n_bytes, total_s)`` samples
    for ``fit_swap_overlap``'s max-plus-launch residual."""
    jax, jnp = _import_jax()
    if jax is None:
        return []
    import numpy as np
    from concurrent.futures import ThreadPoolExecutor
    x = jnp.ones((matmul_dim, matmul_dim), jnp.float32)
    step = jax.jit(lambda a: a @ a)
    jax.block_until_ready(step(x))                 # compile
    t0 = time.perf_counter()
    jax.block_until_ready(step(x))
    compute_s = time.perf_counter() - t0
    samples: List[Tuple[float, int, float]] = []
    with ThreadPoolExecutor(max_workers=1) as pool:
        for n in sizes:
            buf = np.zeros(n, dtype=np.uint8)
            jax.block_until_ready(jax.device_put(buf))   # warm-up
            for _ in range(repeats):
                t0 = time.perf_counter()
                fut = pool.submit(
                    lambda b=buf: jax.block_until_ready(jax.device_put(b)))
                jax.block_until_ready(step(x))
                fut.result()
                samples.append((compute_s, n, time.perf_counter() - t0))
    return samples


def calibrate_link(tm, *, sizes=DEFAULT_SIZES, repeats: int = 3,
                   overlap: bool = True) -> LinkCalibration:
    """Measure the real link and refit ``tm``'s swap terms in place.

    On any failure — jax missing, too few samples, or a degenerate fit
    (non-positive byte rate, as on backends where device transfers are
    aliasing memcpys) — the model's preset terms are restored untouched
    and the returned record says why."""
    snapshot = (tm.swap_byte, tm.swap_floor, tm.swap_launch)

    def _skip(reason: str, backend: str = "unavailable") -> LinkCalibration:
        tm.swap_byte, tm.swap_floor, tm.swap_launch = snapshot
        return LinkCalibration(applied=False, backend=backend,
                               swap_byte=tm.swap_byte,
                               swap_floor=tm.swap_floor,
                               swap_launch=tm.swap_launch, error=reason)

    jax, _ = _import_jax()
    if jax is None:
        return _skip("jax not importable")
    try:
        backend = jax.default_backend()
        samples = measure_link(sizes, repeats) or []
        if len(samples) < 2:
            return _skip("too few transfer samples", backend)
        tm.fit_swap(samples)
        # a fitted rate implying > ~1 PB/s is float noise from size-blind
        # timings (device buffer aliases host memory): nothing real was
        # measured, keep the nominal link pricing
        if tm.swap_byte < 1e-15:
            return _skip("degenerate fit: measured byte rate ~ 0", backend)
        overlap_samples: List[Tuple[float, int, float]] = []
        if overlap:
            overlap_samples = measure_overlap(tm, sizes)
            tm.fit_swap_overlap(overlap_samples)
        cal = LinkCalibration(applied=True, backend=backend,
                              swap_byte=tm.swap_byte,
                              swap_floor=tm.swap_floor,
                              swap_launch=tm.swap_launch,
                              samples=samples,
                              overlap_samples=overlap_samples)
        logger.info("%s", cal.summary())
        return cal
    except Exception as exc:           # never let calibration kill startup
        logger.warning("link calibration failed", exc_info=True)
        return _skip(f"{type(exc).__name__}: {exc}")
