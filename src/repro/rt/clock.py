"""Serving clocks: wall time for live traffic, manual time for tests.

The real-time layer spans two time domains. The backend keeps its own
clock — the engine's modeled (virtual) or measured iteration time — which
prices scheduling decisions, admission verdicts, and every trace
benchmark. The *serving* clock is what the caller experiences: the wall
seconds between submitting a request and receiving its tokens.
``WallClock`` is the production serving clock; ``ManualClock`` freezes the
serving domain so async-lifecycle tests and trace replays through the
real-time loop stay deterministic (the "paused clock" of the
wall-vs-drive equivalence tests).
"""
from __future__ import annotations

import time


class WallClock:
    """Monotonic wall seconds since construction (server start)."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


class ManualClock:
    """A serving clock that only moves when told to — paused by default.

    With this clock the real-time loop runs as fast as the backend steps
    while every wall stamp stays at a known value, making the async path
    bit-comparable to a ``drive()`` trace replay."""

    def __init__(self, t: float = 0.0):
        self._t = t

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0.0, "serving clocks are monotonic"
        self._t += dt
