"""Real-time serving layer: the asyncio front door over ``EchoService``.

``AsyncEchoEngine`` runs the continuous-batching loop as a background
task (``engine.step`` off-thread), stamps live arrivals with real times
for wall-clock admission, streams tokens to ``AsyncRequestHandle``
consumers, and drains gracefully. ``EchoServer`` puts a
newline-delimited-JSON TCP socket in front of it; ``calibrate_link``
refits the ``TimeModel``'s PCIe terms from real ``jax.device_put``
timings at cold start.
"""
from repro.rt.calibrate import (LinkCalibration, calibrate_link,
                                measure_link, measure_overlap)
from repro.rt.clock import ManualClock, WallClock
from repro.rt.engine_loop import AsyncEchoEngine, RTState, RTStats
from repro.rt.handle import (AsyncRequestHandle, AsyncTokenEvent,
                             SubmitQueueFull)
from repro.rt.server import EchoServer, request_once

__all__ = [
    "AsyncEchoEngine", "AsyncRequestHandle", "AsyncTokenEvent",
    "EchoServer", "LinkCalibration", "ManualClock", "RTState", "RTStats",
    "SubmitQueueFull", "WallClock", "calibrate_link", "measure_link",
    "measure_overlap", "request_once",
]
