"""Pallas TPU paged-attention decode kernels.

TPU adaptation of vLLM's PagedAttention: the page indirection lives in the
grid's scalar-prefetched block table — each grid step DMAs one whole KV page
HBM->VMEM via BlockSpec index_map — so the MXU inner loop is dense flash
attention over VMEM tiles (no per-element gather).

Two schedules over the page dimension:

* ``paged_attention`` (legacy): grid (batch, kv_head, num_pages) — one
  running-softmax state walks every page of the max context serially, so
  a single long sequence bounds the whole launch.
* ``paged_attention_splitk`` (flash-decoding): grid (batch, kv_head,
  num_splits, pages_per_split) — the page dimension is partitioned across
  a dedicated grid axis. Each partition carries its own (m, l, acc)
  running-softmax state over at most ``pages_per_split`` pages and writes
  an *unnormalized* partial (acc, m, l); a lightweight cross-partition
  log-sum-exp merge (fused into the same jit) produces the final output.
  Partitions are independent, so on hardware the split axis can fill idle
  cores/lanes for the long-context offline regime, and partitions whose
  pages lie entirely past ``ctx_len`` skip compute (ragged batches stop
  paying for the max context).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables_ref, ctx_lens_ref,          # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                     # VMEM blocks
            out_ref,
            m_ref, l_ref, acc_ref,                   # VMEM scratch
            *, page_size: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)
    ctx = ctx_lens_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * page_size < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = i * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < ctx, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _write():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                    *, interpret: bool = False):
    """q (B,Hq,hd); k/v_pages (P,bs,Hkv,hd); block_tables (B,nblk) int32;
    ctx_lens (B,) int32 -> (B,Hq,hd)."""
    b, hq, hd = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nblk = block_tables.shape[1]
    qg = q.reshape(b, hkv, g, hd)
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bb, h, i, bt, cl: (bb, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, i, bt, cl: (bt[bb, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, i, bt, cl: (bt[bb, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, h, i, bt, cl: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, ctx_lens, qg, k_pages, v_pages)
    return out.reshape(b, hq, hd)


def _splitk_kernel(block_tables_ref, ctx_lens_ref,    # scalar prefetch (SMEM)
                   q_ref, k_ref, v_ref,               # VMEM blocks
                   o_ref, m_out_ref, l_out_ref,       # partial outputs
                   m_ref, l_ref, acc_ref,             # VMEM scratch
                   *, page_size: int, scale: float, pages_per_split: int,
                   nblk: int):
    b = pl.program_id(0)
    s_idx = pl.program_id(2)
    j = pl.program_id(3)
    i = s_idx * pages_per_split + j                   # absolute page index
    ctx = ctx_lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # early exit: pages past the ragged ctx (or past the table on the
    # final, possibly short, split) never touch the MXU
    @pl.when(jnp.logical_and(i < nblk, i * page_size < ctx))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = i * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < ctx, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # partition epilogue: write the *unnormalized* partial — the
    # cross-partition LSE merge divides exactly once, after combining
    @pl.when(j == pages_per_split - 1)
    def _write():
        o_ref[0, 0, 0] = acc_ref[...]
        m_out_ref[0, 0, 0] = m_ref[...]
        l_out_ref[0, 0, 0] = l_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("pages_per_split", "interpret"))
def paged_attention_splitk(q, k_pages, v_pages, block_tables, ctx_lens,
                           *, pages_per_split: int = 4,
                           interpret: bool = False):
    """Split-K / flash-decoding schedule. Same contract as
    ``paged_attention``: q (B,Hq,hd); k/v_pages (P,bs,Hkv,hd);
    block_tables (B,nblk) int32; ctx_lens (B,) int32 -> (B,Hq,hd).

    The page dimension is tiled into ``ceil(nblk / pages_per_split)``
    independent partitions, each producing an unnormalized (acc, m, l)
    triple; the final output is their log-sum-exp merge. A partition whose
    pages all lie past ``ctx_len`` contributes (0, -inf, 0) — exactly the
    identity of the merge — so ragged batches cost only their live pages.
    """
    b, hq, hd = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nblk = block_tables.shape[1]
    pps = max(1, min(pages_per_split, nblk))
    nsplit = pl.cdiv(nblk, pps)
    qg = q.reshape(b, hkv, g, hd)
    scale = 1.0 / (hd ** 0.5)

    def _page(bb, h, s, j, bt, cl):
        # clamp the tail split's overhang onto a valid table entry; the
        # kernel's i < nblk guard skips its compute anyway
        return bt[bb, jnp.minimum(s * pps + j, nblk - 1)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nsplit, pps),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bb, h, s, j, bt, cl: (bb, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, s, j, bt, cl:
                         (_page(bb, h, s, j, bt, cl), 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, s, j, bt, cl:
                         (_page(bb, h, s, j, bt, cl), 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, hd),
                         lambda bb, h, s, j, bt, cl: (bb, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1),
                         lambda bb, h, s, j, bt, cl: (bb, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1),
                         lambda bb, h, s, j, bt, cl: (bb, h, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_splitk_kernel, page_size=page_size, scale=scale,
                          pages_per_split=pps, nblk=nblk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, ctx_lens, qg, k_pages, v_pages)

    # cross-partition combine: one exp re-base per partition, one divide
    # total. Empty partitions (m=-inf, l=0, acc=0) drop out of both sums.
    m_max = jnp.max(m_part, axis=2, keepdims=True)            # (B,K,1,G,1)
    w = jnp.exp(m_part - jnp.maximum(m_max, NEG_INF))         # (B,K,S,G,1)
    l_tot = jnp.sum(w * l_part, axis=2)                       # (B,K,G,1)
    o_tot = jnp.sum(w * o_part, axis=2)                       # (B,K,G,hd)
    out = (o_tot / jnp.maximum(l_tot, 1e-20)).astype(q.dtype)
    return out.reshape(b, hq, hd)
