"""Pallas TPU paged-attention decode kernel.

TPU adaptation of vLLM's PagedAttention: the page indirection lives in the
grid's scalar-prefetched block table — each grid step DMAs one whole KV page
HBM->VMEM via BlockSpec index_map — so the MXU inner loop is dense flash
attention over VMEM tiles (no per-element gather).

Grid: (batch, kv_head, num_pages); flash running-softmax state in VMEM
scratch carries across the page dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables_ref, ctx_lens_ref,          # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                     # VMEM blocks
            out_ref,
            m_ref, l_ref, acc_ref,                   # VMEM scratch
            *, page_size: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)
    ctx = ctx_lens_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * page_size < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = i * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < ctx, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _write():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                    *, interpret: bool = False):
    """q (B,Hq,hd); k/v_pages (P,bs,Hkv,hd); block_tables (B,nblk) int32;
    ctx_lens (B,) int32 -> (B,Hq,hd)."""
    b, hq, hd = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nblk = block_tables.shape[1]
    qg = q.reshape(b, hkv, g, hd)
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bb, h, i, bt, cl: (bb, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, i, bt, cl: (bt[bb, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bb, h, i, bt, cl: (bt[bb, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, h, i, bt, cl: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, ctx_lens, qg, k_pages, v_pages)
    return out.reshape(b, hq, hd)
