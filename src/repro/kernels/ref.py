"""Pure-jnp oracles for every Pallas kernel (and for the engine's CPU path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_paged_attention(q, k_pages, v_pages, block_tables, ctx_lens):
    """Decode attention over a block-paged KV cache.

    q:            (B, Hq, hd)     query for the current token
    k/v_pages:    (P, bs, Hkv, hd) global page pool
    block_tables: (B, nblk) int32 page ids per sequence (padded arbitrarily)
    ctx_lens:     (B,) int32      tokens valid per sequence (incl. current)
    Returns (B, Hq, hd).
    """
    b, hq, hd = q.shape
    p, bs, hkv, _ = k_pages.shape
    nblk = block_tables.shape[1]
    t = nblk * bs
    flat_k = k_pages.reshape(p * bs, hkv, hd)
    flat_v = v_pages.reshape(p * bs, hkv, hd)
    tok = jnp.arange(t)
    idx = block_tables[:, tok // bs] * bs + tok % bs          # (B, T)
    k = flat_k[idx]                                            # (B,T,Hkv,hd)
    v = flat_v[idx]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    mask = tok[None, :] < ctx_lens[:, None]                    # (B,T)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(q.dtype), v)
    return out.reshape(b, hq, hd)


def ref_chunked_prefill_attention(q, k, v, ctx_len):
    """Flash-prefill oracle: q chunk attends to resident prefix + itself.

    q:       (Sc, Hq, hd)  chunk queries (absolute pos = ctx_len + i)
    k/v:     (T, Hkv, hd)  gathered keys: prefix tokens then chunk tokens;
                           rows >= ctx_len + Sc are padding.
    ctx_len: scalar int32
    Returns (Sc, Hq, hd).
    """
    sc, hq, hd = q.shape
    t, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(sc, hkv, g, hd)
    scores = jnp.einsum("skgd,tkd->kgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    i = jnp.arange(sc)[:, None]
    j = jnp.arange(t)[None, :]
    mask = j <= (ctx_len + i)                                  # causal w/ offset
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("kgst,tkd->skgd", probs.astype(q.dtype), v)
    return out.reshape(sc, hq, hd)


def ref_rglru_scan(a, b):
    """Sequential RG-LRU recurrence oracle: h_t = a_t h_{t-1} + b_t.

    a, b: (B, S, W) -> (B, S, W) fp32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    h0 = jnp.zeros(a[:, 0].shape, jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)


def ref_ssd_sequential(x, dt_a, b_mat, c_mat, initial_state=None):
    """Sequential SSD scan oracle.

    x:     (B, S, H, P)  dt-scaled inputs
    dt_a:  (B, S, H)     A*dt (negative)
    b/c:   (B, S, N)
    Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 math.
    """
    bs, s, h, p = x.shape
    n = b_mat.shape[-1]
    x = x.astype(jnp.float32)
    dt_a = dt_a.astype(jnp.float32)
    b_mat = b_mat.astype(jnp.float32)
    c_mat = c_mat.astype(jnp.float32)
    state0 = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        xt, at, bt, ct = inp          # (B,H,P), (B,H), (B,N), (B,N)
        state = state * jnp.exp(at)[..., None, None] + xt[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), dt_a.transpose(1, 0, 2),
          b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), final
