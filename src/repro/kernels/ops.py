"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness validation; on TPU they compile natively. Callers can force a
path via ``impl`` ("pallas" | "ref").
"""
from __future__ import annotations

import jax

from repro.kernels import ref as ref_mod
from repro.kernels.chunked_prefill import chunked_prefill_attention as _pallas_chunked
from repro.kernels.paged_attention import paged_attention as _pallas_paged
from repro.kernels.ssd_scan import ssd_scan as _pallas_ssd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, impl="pallas"):
    if impl == "ref":
        return ref_mod.ref_paged_attention(q, k_pages, v_pages, block_tables, ctx_lens)
    return _pallas_paged(q, k_pages, v_pages, block_tables, ctx_lens,
                         interpret=_interpret())


def chunked_prefill_attention(q, k, v, ctx_len, impl="pallas", blk_q=128, blk_k=128):
    if impl == "ref":
        return ref_mod.ref_chunked_prefill_attention(q, k, v, ctx_len)
    return _pallas_chunked(q, k, v, ctx_len, blk_q=blk_q, blk_k=blk_k,
                           interpret=_interpret())


def ssd_scan(x, dt_a, b_mat, c_mat, chunk=64, impl="pallas"):
    if impl == "ref":
        y, fs = ref_mod.ref_ssd_sequential(x, dt_a, b_mat, c_mat)
        return y, fs
    return _pallas_ssd(x, dt_a, b_mat, c_mat, chunk=chunk, interpret=_interpret())


def rglru_scan(a, b, chunk=64, impl="pallas"):
    from repro.kernels.rglru_scan import rglru_scan as _pallas_rglru
    if impl == "ref":
        return ref_mod.ref_rglru_scan(a, b)
    return _pallas_rglru(a, b, chunk=chunk, interpret=_interpret())
