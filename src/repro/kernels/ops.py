"""Jit'd dispatch wrappers + per-preset block-size tuning for the Pallas
kernels.

On CPU (this container) the Pallas kernels execute in interpret mode for
correctness validation; on TPU they compile natively. Callers can force a
path via ``impl``:

* ``"ref"``    — the pure-jnp oracle (the fast, XLA-compiled CPU path);
* ``"pallas"`` — the legacy serial-page / fixed-grid Pallas kernels;
* ``"splitk"`` — the split-K / flash-decoding paged-attention schedule
  (decode only; prefill always uses the fused chunked kernel);
* ``"auto"``   — ``"ref"`` on CPU (interpret mode is a correctness tool,
  not a fast path), ``"splitk"`` on accelerators.

Block sizes and the split factor come from per-hardware tuning tables
(``KernelTuning`` presets, mirroring ``TimeModel.a100()/h100()``): the
A100 table favors smaller K tiles and split factor (40 GB/s-class HBM,
108 SMs); the H100 table doubles both (3.35 TB/s HBM, more parallelism to
feed). ``kernel_tuning(profile)`` resolves a profile name — or the
current backend when ``profile`` is None — so ``PagedRunner`` and the
benchmarks pick tuned ``blk_q/blk_k/pages_per_split`` per hardware.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax

from repro.kernels import ref as ref_mod
from repro.kernels.chunked_prefill import chunked_prefill_attention as _pallas_chunked
from repro.kernels.paged_attention import paged_attention as _pallas_paged
from repro.kernels.paged_attention import paged_attention_splitk as _pallas_splitk
from repro.kernels.ssd_scan import ssd_scan as _pallas_ssd


@dataclass(frozen=True)
class KernelTuning:
    """Per-hardware kernel launch parameters.

    blk_q/blk_k: chunked-prefill flash tile sizes (queries x keys);
    pages_per_split: pages per split-K decode partition — smaller splits
    expose more parallelism for long contexts, larger ones amortize the
    cross-partition merge.
    """
    blk_q: int = 128
    blk_k: int = 128
    pages_per_split: int = 4

    def override(self, **kw) -> "KernelTuning":
        return replace(self, **{k: v for k, v in kw.items() if v is not None})


TUNING_PRESETS = {
    # A100-40G: 1.5 TB/s HBM, 108 SMs — modest tiles, modest split
    "a100": KernelTuning(blk_q=128, blk_k=128, pages_per_split=8),
    # H100-80G: 3.35 TB/s HBM — wider K tiles keep the MXU fed, deeper
    # splits fill the extra parallelism on long offline contexts
    "h100": KernelTuning(blk_q=128, blk_k=256, pages_per_split=16),
    # CPU / interpret: small tiles keep the (slow) interpreter tractable
    # and exercise multi-block grids at test shapes
    "cpu": KernelTuning(blk_q=64, blk_k=64, pages_per_split=4),
}


def kernel_tuning(profile: str | None = None) -> KernelTuning:
    """Resolve a tuning table: explicit profile name, else by backend."""
    if profile is None:
        profile = "cpu" if jax.default_backend() == "cpu" else "a100"
    if profile not in TUNING_PRESETS:
        raise ValueError(f"unknown kernel tuning profile {profile!r}; "
                         f"have {sorted(TUNING_PRESETS)}")
    return TUNING_PRESETS[profile]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "ref" if jax.default_backend() == "cpu" else "splitk"
    return impl


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                    impl="pallas", preset=None, pages_per_split=None):
    """Decode attention dispatch. ``impl`` in {auto, ref, pallas, splitk};
    ``preset`` picks the tuning table for the split factor, overridable
    via ``pages_per_split``."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref_mod.ref_paged_attention(q, k_pages, v_pages, block_tables,
                                           ctx_lens)
    if impl == "splitk":
        tune = kernel_tuning(preset).override(pages_per_split=pages_per_split)
        return _pallas_splitk(q, k_pages, v_pages, block_tables, ctx_lens,
                              pages_per_split=tune.pages_per_split,
                              interpret=_interpret())
    return _pallas_paged(q, k_pages, v_pages, block_tables, ctx_lens,
                         interpret=_interpret())


def chunked_prefill_attention(q, k, v, ctx_len, impl="pallas", preset=None,
                              blk_q=None, blk_k=None):
    """Chunked-prefill dispatch (fused-epilogue kernel on the Pallas
    paths). Tile sizes default to the preset's tuning table."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref_mod.ref_chunked_prefill_attention(q, k, v, ctx_len)
    tune = kernel_tuning(preset).override(blk_q=blk_q, blk_k=blk_k)
    return _pallas_chunked(q, k, v, ctx_len, blk_q=tune.blk_q,
                           blk_k=tune.blk_k, interpret=_interpret())


def ssd_scan(x, dt_a, b_mat, c_mat, chunk=64, impl="pallas"):
    if _resolve(impl) == "ref":
        y, fs = ref_mod.ref_ssd_sequential(x, dt_a, b_mat, c_mat)
        return y, fs
    return _pallas_ssd(x, dt_a, b_mat, c_mat, chunk=chunk, interpret=_interpret())


def rglru_scan(a, b, chunk=64, impl="pallas"):
    from repro.kernels.rglru_scan import rglru_scan as _pallas_rglru
    if _resolve(impl) == "ref":
        return ref_mod.ref_rglru_scan(a, b)
    return _pallas_rglru(a, b, chunk=chunk, interpret=_interpret())
