"""Pallas TPU RG-LRU linear-recurrence scan kernel.

h_t = a_t * h_{t-1} + b_t, elementwise over the LRU width. The recurrence
is VPU-bound and inherently sequential in t, so the kernel optimizes the
memory system instead: the sequence is streamed chunk-by-chunk through VMEM
(each a/b tile read from HBM exactly once) with the carried state living in
a VMEM scratch across the sequential innermost grid dim — the same
state-carry pattern as ssd_scan.

Grid: (batch, width_blocks, chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, y_ref, h_ref, *, chunk: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # (chunk, W_blk)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "blk_w"))
def rglru_scan(a, b, *, chunk: int = 64, interpret: bool = False,
               blk_w: int = 128):
    """a, b: (B, S, W) -> h per step (B, S, W), fp32."""
    bsz, s, w = a.shape
    assert s % chunk == 0
    blk_w = min(blk_w, w)
    assert w % blk_w == 0
    grid = (bsz, w // blk_w, s // chunk)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, blk_w), lambda bb, wv, i: (bb, i, wv)),
            pl.BlockSpec((1, chunk, blk_w), lambda bb, wv, i: (bb, i, wv)),
        ],
        out_specs=pl.BlockSpec((1, chunk, blk_w), lambda bb, wv, i: (bb, i, wv)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out
