"""Pallas TPU chunked-prefill flash-attention kernel.

Computes one prefill chunk's queries against the resident prefix + the
chunk itself (Sarathi-style chunked prefill — the batching substrate Echo
schedules over). Causal block-skipping: K blocks entirely above the
diagonal are never brought into VMEM, and blocks entirely *below* the
causal frontier take a mask-free fast path (only diagonal-straddling
blocks pay the iota/where).

The epilogue is fused: the final grid step normalizes by the running
softmax denominator, zeroes padded query rows, and casts to the output
dtype inside the kernel — no separate normalization/cleanup pass over the
output. Non-divisible shapes are handled by the wrapper padding q/k/v up
to the block grid (padded K rows sit past ctx+Sc, so causality masks
them; padded Q rows are zeroed by the epilogue and sliced off).

Grid: (q_head, q_blocks, k_blocks); running-softmax scratch in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(ctx_ref,                                  # scalar prefetch
            q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref,
            *, blk_q: int, blk_k: int, scale: float, group: int,
            sc_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    ctx = ctx_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute pos of q row r: ctx + iq*blk_q + r ; K col c: ik*blk_k + c
    # block is live unless its first col exceeds the last row's position;
    # it is mask-free when its last col can't exceed the first row's
    first_q_pos = ctx + iq * blk_q
    last_q_pos = first_q_pos + blk_q - 1
    live = ik * blk_k <= last_q_pos
    full = (ik + 1) * blk_k - 1 <= first_q_pos

    def _accumulate(s):
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v_ref[:, 0, :].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    def _scores():
        q = q_ref[:, 0, :].astype(jnp.float32)        # (blk_q, hd)
        k = k_ref[:, 0, :].astype(jnp.float32)        # (blk_k, hd)
        return jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32) * scale

    @pl.when(jnp.logical_and(live, full))
    def _compute_unmasked():                          # below the diagonal
        _accumulate(_scores())

    @pl.when(jnp.logical_and(live, jnp.logical_not(full)))
    def _compute_masked():                            # straddles the diagonal
        s = _scores()
        rows = ctx + iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _accumulate(jnp.where(cols <= rows, s, NEG_INF))

    # fused epilogue: normalize + zero padded q rows + cast, in one write
    @pl.when(ik == nk - 1)
    def _write():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        rows = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
        out = jnp.where(rows < sc_valid, out, 0.0)
        out_ref[:, 0, :] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk_q", "blk_k", "interpret"))
def chunked_prefill_attention(q, k, v, ctx_len, *, blk_q: int = 128,
                              blk_k: int = 128, interpret: bool = False):
    """q (Sc,Hq,hd); k/v (T,Hkv,hd); ctx_len scalar int32 -> (Sc,Hq,hd).

    Rows of k/v beyond ctx_len + Sc are padding (masked by causality).
    Sc and T need not divide the block sizes: inputs are zero-padded up to
    the (blk_q, blk_k) grid and the fused epilogue zeroes the padded rows
    before the wrapper slices them off.
    """
    sc, hq, hd = q.shape
    t, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    ctx = jnp.asarray(ctx_len, jnp.int32).reshape(1)

    blk_q = min(blk_q, max(sc, 1))
    blk_k = min(blk_k, max(t, 1))
    sc_p = pl.cdiv(sc, blk_q) * blk_q
    t_p = pl.cdiv(t, blk_k) * blk_k
    if sc_p != sc:
        q = jnp.pad(q, ((0, sc_p - sc), (0, 0), (0, 0)))
    if t_p != t:
        # padded K rows land at positions >= T >= ctx + Sc, above every
        # query's causal frontier — masked like any other future token
        k = jnp.pad(k, ((0, t_p - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, t_p - t), (0, 0), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(hq, sc_p // blk_q, t_p // blk_k),
        in_specs=[
            pl.BlockSpec((blk_q, 1, hd), lambda h, iq, ik, c: (iq, h, 0)),
            pl.BlockSpec((blk_k, 1, hd), lambda h, iq, ik, c: (ik, h // g, 0)),
            pl.BlockSpec((blk_k, 1, hd), lambda h, iq, ik, c: (ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((blk_q, 1, hd), lambda h, iq, ik, c: (iq, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k, scale=scale,
                          group=g, sc_valid=sc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((sc_p, hq, hd), q.dtype),
        interpret=interpret,
    )(ctx, q, k, v)
    return out[:sc] if sc_p != sc else out
