"""Pallas TPU Mamba-2 SSD chunk-scan kernel.

One grid step processes one (batch, head, chunk): intra-chunk quadratic
attention-like term via the MXU, inter-chunk linear recurrence carried in a
VMEM state scratch across the (sequential, innermost) chunk grid dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, a_ref, b_ref, c_ref,          # VMEM blocks
            y_ref, fs_ref,                        # outputs
            state_ref,                            # VMEM scratch (P, N) fp32
            *, chunk: int):
    i = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0, :, 0].astype(jnp.float32)                      # (L,)
    xc = x_ref[0, :, 0, :].astype(jnp.float32)                  # (L, P)
    bc = b_ref[0].astype(jnp.float32)                           # (L, N)
    cc = c_ref[0].astype(jnp.float32)                           # (L, N)
    a_cum = jnp.cumsum(a)                                       # (L,)

    # intra-chunk: scores[s, t] = C_s . B_t * exp(sum_{t<u<=s} a_u), t <= s
    seg = a_cum[:, None] - a_cum[None, :]                       # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y = jax.lax.dot(scores, xc, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                                      # (P, N)
    y += jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        cc, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (L, P)

    # state update: state' = state * exp(A_chunk) + sum_t exp(A_cum[-1]-A_cum[t]) x_t B_t^T
    decay_states = jnp.exp(a_cum[-1] - a_cum)                   # (L,)
    xb = jax.lax.dot_general(xc * decay_states[:, None], bc,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state * jnp.exp(a_cum[-1]) + xb

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(i == nc - 1)
    def _write():
        fs_ref[0, 0] = state_ref[...].astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt_a, b_mat, c_mat, *, chunk: int = 64,
             interpret: bool = False):
    """x (B,S,H,P) dt-scaled; dt_a (B,S,H); b/c (B,S,N).

    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    grid = (bsz, h, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, i: (b, i, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, i: (b, i, hh)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, i: (b, i, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, i: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt_a, b_mat, c_mat)
    return out[0], out[1]
