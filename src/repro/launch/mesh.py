"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips, the pod axis
crossing DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
