"""Divisibility-aware logical sharding rules + dry-run input specs.

Parameters shard on the `model` axis by name-based rules (Megatron-style
tensor parallelism + expert parallelism); activations/batches shard on
(`pod`, `data`). Any dim not divisible by its mesh axes is replicated —
this is what lets one rule set serve MQA (kv=1), 24-head MHA, 128-expert
MoE etc. without per-arch special cases.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import hooks
from repro.models.model import Model

# name -> {trailing_ndim: spec_from_end}; 'model' entries are
# divisibility-checked per tensor.
_PARAM_RULES = {
    "embed": {2: ("model", None)},
    "unembed": {2: (None, "model")},
    "mm_proj": {2: (None, None)},
    "wq": {3: (None, "model", None)},
    "wk": {3: (None, "model", None)},
    "wv": {3: (None, "model", None)},
    "wo": {3: ("model", None, None), 2: ("model", None)},   # attn / rglru
    "w1": {2: (None, "model")},
    "w3": {2: (None, "model")},
    "w2": {2: ("model", None)},
    "router": {2: (None, "model")},
    "we1": {3: ("model", None, None)},
    "we3": {3: ("model", None, None)},
    "we2": {3: ("model", None, None)},
    "z_proj": {2: (None, "model")},
    "x_proj": {2: (None, "model")},
    "dt_proj": {2: (None, "model")},
    # b_proj / c_proj / conv_bc replicated (B,C are shared across heads)
    "out_proj": {2: ("model", None)},
    "conv_w": {2: (None, "model")},
    "conv_x": {2: (None, "model")},
    "wx": {2: (None, "model")},
    "wg": {2: (None, "model")},
}


def _axes_fit(dim: int, axes, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    """Largest prefix of `axes` whose size product divides `dim`."""
    if isinstance(axes, str):
        axes = (axes,)
    prod = 1
    used = []
    for a in axes:
        if a not in mesh.shape:
            continue
        if dim % (prod * mesh.shape[a]) == 0:
            prod *= mesh.shape[a]
            used.append(a)
        else:
            break
    return tuple(used) if used else None


def _leaf_spec(path_names, leaf, mesh: Mesh, extra_axes=()) -> P:
    """Match on the last path name; stacked (scan) params carry extra
    leading dims, so rules apply to the *trailing* ndim. ``extra_axes``
    are appended after `model` on the sharded dim (ZeRO-style: optimizer
    moments also shard across the data axes)."""
    name = path_names[-1] if path_names else ""
    rule = _PARAM_RULES.get(name)
    nd = leaf.ndim
    if rule:
        for t_nd in sorted(rule, reverse=True):
            if nd >= t_nd:
                spec = rule[t_nd]
                lead = (None,) * (nd - t_nd)
                tail = tuple(
                    _axes_fit(leaf.shape[nd - t_nd + i],
                              (s,) + tuple(extra_axes) if isinstance(s, str)
                              else s, mesh) if s else None
                    for i, s in enumerate(spec))
                return P(*(lead + tail))
    return P(*((None,) * nd))


def param_shardings(params_specs, mesh: Mesh, extra_axes=()):
    """Pytree of NamedSharding matching the param-spec pytree."""
    def walk(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None))
                 for k in path]
        names = [n for n in names if isinstance(n, str)]
        return NamedSharding(mesh, _leaf_spec(names, leaf, mesh, extra_axes))
    return jax.tree_util.tree_map_with_path(walk, params_specs)


# --------------------------------------------------------------- hook
_LOGICAL = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
}


def install_hook(mesh: Mesh) -> None:
    def hook(x, logical_axes):
        spec = []
        for i, (dim, name) in enumerate(zip(x.shape, logical_axes)):
            if name == "seq_fallback":
                # shard this (seq) dim on `model` ONLY when the tensor's
                # head dim (the next axis named heads/kv_heads) cannot be
                # sharded — sequence-parallel attention fallback.
                head_i = next((j for j, n in enumerate(logical_axes)
                               if n in ("heads", "kv_heads")), None)
                head_ok = (head_i is not None and
                           _axes_fit(x.shape[head_i], ("model",), mesh))
                spec.append(None if head_ok
                            else _axes_fit(dim, ("model",), mesh))
                continue
            if name is None or name not in _LOGICAL:
                spec.append(None)
                continue
            spec.append(_axes_fit(dim, _LOGICAL[name], mesh))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    hooks.set_hook(hook)


def batch_spec(batch: int, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    return _axes_fit(batch, ("pod", "data"), mesh)


# --------------------------------------------------------------- inputs
def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """ShapeDtypeStruct stand-ins + NamedShardings for one workload shape.

    Returns (args_specs: dict, args_shardings: dict) for the step function
    of that shape kind (train/prefill: token batch; decode: token + cache).
    """
    model = Model(cfg)
    b, s = shape.global_batch, shape.seq_len
    baxes = batch_spec(b, mesh)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_sh = NamedSharding(mesh, P(baxes, None))

    if shape.kind == "train":
        args = {"tokens": tok, "labels": tok}
        shard = {"tokens": tok_sh, "labels": tok_sh}
        if cfg.multimodal:
            args["mm_embeds"] = jax.ShapeDtypeStruct(
                (b, 256, cfg.mm_embed_dim), jnp.float32)
            shard["mm_embeds"] = NamedSharding(mesh, P(baxes, None, None))
        return args, shard

    if shape.kind == "prefill":
        args = {"tokens": tok}
        shard = {"tokens": tok_sh}
        if cfg.multimodal:
            args["mm_embeds"] = jax.ShapeDtypeStruct(
                (b, 256, cfg.mm_embed_dim), jnp.float32)
            shard["mm_embeds"] = NamedSharding(mesh, P(baxes, None, None))
        return args, shard

    # decode: one new token against a cache of seq_len positions
    cache_specs = model.make_cache(b, s, as_specs=True)
    cache_shard = cache_shardings(model, cache_specs, mesh)
    args = {
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache_specs,
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    shard = {
        "tokens": NamedSharding(mesh, P(baxes)),
        "cache": cache_shard,
        "pos": NamedSharding(mesh, P(baxes)),
    }
    return args, shard


def cache_shardings(model: Model, cache_specs, mesh: Mesh):
    """attn k/v (B,S,Hkv,hd): batch x data, heads x model (if divisible);
    ssm/rglru states: batch x data, inner dims x model (if divisible)."""
    def leaf(path, spec):
        names = [getattr(k, "key", None) for k in path]
        names = [n for n in names if isinstance(n, str)]
        nd = spec.ndim
        shape = spec.shape
        b_dim = nd - 4 if nd >= 4 and names and names[-1] in ("k", "v") else None
        out = [None] * nd
        if names and names[-1] in ("k", "v"):
            # (..., B, S, Hkv, hd): heads on model when divisible, else
            # shard the cache SEQ dim (sequence-parallel decode attention)
            out[nd - 4] = _axes_fit(shape[nd - 4], ("pod", "data"), mesh)
            heads_fit = _axes_fit(shape[nd - 2], ("model",), mesh)
            if heads_fit:
                out[nd - 2] = heads_fit
            else:
                out[nd - 3] = _axes_fit(shape[nd - 3], ("model",), mesh)
        elif names and names[-1] == "conv":
            out[nd - 3] = _axes_fit(shape[nd - 3], ("pod", "data"), mesh)
            out[nd - 1] = _axes_fit(shape[nd - 1], ("model",), mesh)
        elif names and names[-1] == "ssd":
            # (..., B, H, P, N)
            out[nd - 4] = _axes_fit(shape[nd - 4], ("pod", "data"), mesh)
            out[nd - 3] = _axes_fit(shape[nd - 3], ("model",), mesh)
        elif names and names[-1] == "h":
            out[nd - 2] = _axes_fit(shape[nd - 2], ("pod", "data"), mesh)
            out[nd - 1] = _axes_fit(shape[nd - 1], ("model",), mesh)
        return NamedSharding(mesh, P(*out))
    return jax.tree_util.tree_map_with_path(leaf, cache_specs)
