"""Training driver (the train_4k substrate, reduced configs on CPU).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.training import adamw_init, make_train_step
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--save", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, total_steps=args.steps))
    stream = TokenStream(cfg.vocab_size, seed=args.seed)
    mm_dim = cfg.mm_embed_dim if cfg.multimodal else None

    t0 = time.time()
    for i, batch in enumerate(stream.batches(args.batch, args.seq, mm_dim)):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, jb)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(time.time() - t0):.1f}s", flush=True)
        if i + 1 >= args.steps:
            break
    if args.save:
        ckpt.save(args.save, params, step=args.steps)
        print(f"saved checkpoint to {args.save}.npz")


if __name__ == "__main__":
    main()
