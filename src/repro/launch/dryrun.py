import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without hardware: pjit partitions
the step function over the production mesh using ShapeDtypeStruct stand-ins
(no allocation). Records memory_analysis, cost_analysis and the collective
schedule (parsed from HLO) for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out DIR]
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (input_specs, install_hook,
                                   param_shardings)
from repro.models import hooks
from repro.models.model import Model
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    HLO. Returns {op_name: bytes, ..., 'total': bytes, 'count': n}."""
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.+?)\s*(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        shapes_part = m.group(1)
        op = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            nbytes += size * _DTYPE_BYTES[dt]
        out[op] += nbytes
        count += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


def _cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on new jax and a
    one-element list of dicts on older releases (e.g. 0.4.x) — normalize."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _cache_len(cfg, shape) -> int:
    return Model(cfg).attn_cache_len(shape.seq_len)


def build_step(cfg, shape, mesh):
    """Returns (jitted_fn, args_dict) ready to .lower(**args)."""
    model = Model(cfg)
    args, shard = input_specs(cfg, shape, mesh)
    pspecs = model.param_specs()
    psh = param_shardings(pspecs, mesh)

    if shape.kind == "train":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.sharding import param_shardings as psh_fn
        from repro.training.optimizer import AdamWState
        step = make_train_step(model)
        opt_specs = jax.eval_shape(adamw_init, pspecs)
        # optimizer m/v shard like params PLUS across the data axes
        # (ZeRO-1): fp32 moments replicated over DP do not fit HBM
        mv_sh = psh_fn(pspecs, mesh, extra_axes=("data", "pod"))
        opt_sh = AdamWState(step=NamedSharding(mesh, P()), m=mv_sh, v=mv_sh)
        fn = jax.jit(step,
                     in_shardings=(psh, opt_sh, shard),
                     donate_argnums=(0, 1))
        lower_args = (pspecs, opt_specs, args)
        return fn, lower_args

    if shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch["tokens"],
                                 mm_embeds=batch.get("mm_embeds"))
        fn = jax.jit(prefill, in_shardings=(psh, shard))
        return fn, (pspecs, args)

    # decode
    def decode(params, batch):
        return model.decode_step(params, batch["tokens"], batch["cache"],
                                 batch["pos"])
    fn = jax.jit(decode, in_shardings=(psh, shard),
                 donate_argnums=())
    return fn, (pspecs, args)


def _measure(cfg, shape, mesh) -> dict:
    """flops / bytes / collective bytes of one compile."""
    fn, lower_args = build_step(cfg, shape, mesh)
    lowered = fn.lower(*lower_args)
    compiled = lowered.compile()
    ca = _cost_dict(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": collective_bytes(compiled.as_text()),
        "compiled": compiled,
    }


def probe_corrected(cfg, shape, mesh) -> dict:
    """XLA cost_analysis counts a while-loop body once, not x trips. Probe
    with 1-unit and 2-unit *unrolled* stacks to solve
      total = nonloop + n_units * body   (per metric)
    Remainder layers (hybrid tail) are approximated as a body fraction."""
    import dataclasses
    from repro.models import transformer as tfm
    unit = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_units = cfg.num_layers // unit
    rem = cfg.num_layers - n_units * unit
    cfg1 = dataclasses.replace(cfg, num_layers=unit)
    cfg2 = dataclasses.replace(cfg, num_layers=2 * unit)
    tfm.set_unroll(True)
    try:
        m1 = _measure(cfg1, shape, mesh)
        m2 = _measure(cfg2, shape, mesh)
    finally:
        tfm.set_unroll(False)

    m1.pop("compiled", None)
    m2.pop("compiled", None)

    def corr(key):
        body = m2[key] - m1[key]
        nonloop = m1[key] - body
        return max(nonloop, 0.0) + (n_units + rem / unit) * max(body, 0.0)

    coll_body = {k: m2["coll"][k] - m1["coll"][k]
                 for k in m1["coll"] if k != "count"}
    coll_nonloop = {k: m1["coll"][k] - coll_body[k] for k in coll_body}
    coll = {k: max(coll_nonloop[k], 0) + (n_units + rem / unit) * max(coll_body[k], 0)
            for k in coll_body}
    return {"flops": corr("flops"), "bytes": corr("bytes"),
            "collectives": coll}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": mesh.size, "ok": False}
    t0 = time.time()
    try:
        install_hook(mesh)
        with mesh:
            fn, lower_args = build_step(cfg, shape, mesh)
            lowered = fn.lower(*lower_args)
            compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ca = _cost_dict(compiled)
        rec["flops"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory"] = {
                    "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                    "output_bytes": getattr(ma, "output_size_in_bytes", None),
                    "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                    "generated_code_bytes":
                        getattr(ma, "generated_code_size_in_bytes", None),
                }
        except Exception:
            rec["memory"] = None
        rec["collectives"] = collective_bytes(compiled.as_text())
        del compiled, lowered, fn
        # roofline metrics from unrolled probes (scan bodies counted once
        # by cost_analysis — see probe_corrected)
        corr = probe_corrected(cfg, shape, mesh)
        rec["corrected"] = corr
        peak_flops = 197e12        # bf16 / chip (TPU v5e)
        hbm_bw = 819e9             # B/s / chip
        ici_bw = 50e9              # B/s / link
        rec["roofline"] = {
            "compute_s": corr["flops"] / peak_flops,
            "memory_s": corr["bytes"] / hbm_bw,
            "collective_s": corr["collectives"]["total"] / ici_bw,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom
        # MODEL_FLOPS (useful compute): 6*N_active*D train, 2*N_active*D fwd
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mult = 6 if shape.kind == "train" else 2
        rec["model_flops_global"] = mult * cfg.active_param_count * tokens
        rec["model_flops_per_chip"] = rec["model_flops_global"] / mesh.size
        if corr["flops"] > 0:
            rec["useful_ratio"] = rec["model_flops_per_chip"] / corr["flops"]
        rec["ok"] = True
    except Exception as e:  # a failure here is a bug in the system
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        hooks.clear_hook()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.json".replace("/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')})"
        extra = ""
        if rec["ok"]:
            extra = (f" flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e}"
                     f" coll={rec['collectives']['total']:.3e}"
                     f" t={rec['compile_s']}s")
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {status}{extra}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    archs = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(INPUT_SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multipod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for a, s, mp in combos:
        rec = run_one(a, s, mp, args.out)
        failures += 0 if rec["ok"] else 1
    print(f"[dryrun] done: {len(combos) - failures}/{len(combos)} OK")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
