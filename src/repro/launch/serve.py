"""Co-serving driver: run the Echo engine on a reduced-family model.

The full assigned configs are exercised by the dry-run (``dryrun.py``);
this driver serves a runnable-on-CPU reduced variant with a real bursty
online trace + offline batch corpus, and prints the paper's metrics.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --policy Echo --duration 30

With ``--replicas N`` (N > 1) the driver instead dry-runs a cluster of N
virtual-clock replicas behind the prefix-affinity router on a multi-tenant
workload — no model execution, the §5.4 simulator methodology fleet-wide:

  PYTHONPATH=src python -m repro.launch.serve --replicas 4 --router affinity

Ground truth vs. estimate (§5 calibration loop): ``--hw-profile`` selects
the true hardware clock (comma-separated to cycle profiles over a
heterogeneous fleet), ``--hw-drift``/``--hw-jitter`` perturb it away from
the scheduler's stock A100 estimate, and ``--calibrate`` turns on the
online refitting that closes the gap:

  PYTHONPATH=src python -m repro.launch.serve --replicas 2 \
      --hw-profile a100,h100 --hw-drift 2.0 --calibrate

Both paths drive the workload through the one ``EchoService`` facade
(``repro.serving``); ``--max-online-queue`` / ``--slo-shed-factor`` /
``--offline-cap`` turn on its admission backpressure.

KV tiering: ``--host-kv-gb`` attaches a host-memory swap tier (per replica
on the cluster path) sized in GB, ``--pcie-gbps`` sets the transfer-term
bandwidth, ``--no-swap`` forces the recompute-only baseline, and
``--no-swap-overlap`` charges transfers serially instead of overlapping
them with compute on the async copy stream:

  PYTHONPATH=src python -m repro.launch.serve --host-kv-gb 4 --pcie-gbps 25

Real-time serving: ``--serve`` listens on a TCP socket instead of replaying
a canned trace — the ``repro.rt`` asyncio front door (continuous-batching
loop, wall-clock admission, streaming handles, graceful drain on Ctrl-C).
At startup the PCIe swap terms are refit from real ``jax.device_put``
timings (skip with ``--no-link-calibration``); ``--virtual`` serves the
model-free virtual-clock engine for protocol demos:

  PYTHONPATH=src python -m repro.launch.serve --serve --port 8631
  PYTHONPATH=src python -m repro.launch.serve --serve --virtual \
      --max-online-queue 64
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import ALL_POLICIES, SLO, EchoEngine, TimeModel
from repro.core.block_io import BlockIOSpec, io_spec_for_model, paged_spec
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.models import Model
from repro.serving import AdmissionConfig, EchoService

POLICY_BY_NAME = {p.name: p for p in ALL_POLICIES}

DEFAULT_ARCH = "qwen3-4b"


def host_kv_blocks(args, io: BlockIOSpec = None,
                   block_size: int = 16) -> int:
    """--host-kv-gb translated to host-tier slots through the served
    family's block I/O spec (0 with --no-swap): one slot parks one block's
    payload — a page of KV for attention models, one fixed-size state
    snapshot for SSM/hybrid ones — so the same GB budget buys far more
    slots on a state-family model."""
    if args.no_swap or args.host_kv_gb <= 0:
        return 0
    per_block = max((io or paged_spec()).block_bytes(block_size), 1)
    return max(int(args.host_kv_gb * 1e9 / per_block), 1)


def admission_config(args):
    """AdmissionConfig from the backpressure flags; None = legacy unbounded."""
    cfg = AdmissionConfig(max_online_queue=args.max_online_queue,
                          slo_shed_factor=args.slo_shed_factor,
                          offline_pool_cap=args.offline_cap)
    return cfg if cfg.active else None


def setup_obs(args, service: EchoService):
    """Attach the observability layer when --trace-out/--metrics-out ask
    for it. Returns (tracer, registry), both None when disabled."""
    if not (args.trace_out or args.metrics_out):
        return None, None
    from repro.obs import MetricsRegistry, Tracer
    tracer = Tracer(cap=args.trace_cap) if args.trace_out else None
    registry = MetricsRegistry()
    service.instrument(registry, tracer)
    return tracer, registry


def write_obs(args, tracer, registry) -> None:
    if tracer is not None and args.trace_out:
        tracer.write(args.trace_out)
        print(f"trace: {args.trace_out} ({len(tracer._events)} events, "
              f"{tracer.dropped_events} dropped; "
              f"{len(tracer.preempted_rids())} preempted / "
              f"{len(tracer.swapped_rids())} swapped requests) — "
              "load at https://ui.perfetto.dev")
    if registry is not None and args.metrics_out:
        registry.write(args.metrics_out)
        print(f"metrics: {args.metrics_out}")


def print_report(service: EchoService, stats, online, offline) -> None:
    """One reporter for both the single-engine and the cluster path — the
    metric surface is identical; only the per-engine detail lines vary."""
    m = stats.merged() if hasattr(stats, "merged") else stats
    on_done = sum(1 for r in m.finished if r.is_online)
    off_done = len(m.finished) - on_done
    print(f"online finished: {on_done}/{len(online)}  "
          f"offline finished: {off_done}/{len(offline)}")
    print(f"offline throughput: {stats.offline_throughput():.1f} "
          f"tok/s (virtual)")
    print(f"SLO attainment: TTFT {stats.slo_attainment('ttft'):.3f}  "
          f"TPOT {stats.slo_attainment('tpot'):.3f}")
    pcts = service.live.percentiles()
    if pcts:
        print("latency percentiles (s):")
        for name in ("ttft", "tpot", "queue_delay"):
            if name in pcts:
                v = pcts[name]
                print(f"  {name:>11}: p50 {v['p50']:.4f}  "
                      f"p90 {v['p90']:.4f}  p99 {v['p99']:.4f}")
    if service.live.shed or service.live.aborted:
        print(f"admission: shed {service.live.shed}  "
              f"aborted {service.live.aborted}")
    router = getattr(stats, "router", None)
    if router is not None:
        print(f"router: affinity hits {router.affinity_hits}/"
              f"{router.offline_dispatched}  "
              f"stolen {router.stolen_requests}")
    if service.live.swap_ins or service.live.swap_outs:
        print(f"kv swap: in {service.live.swapped_in_tokens} tok "
              f"({service.live.swap_ins} events)  "
              f"out {service.live.swapped_out_tokens} tok "
              f"({service.live.swap_outs} events)")
    if service.live.swap_transfer_time > 0:
        print(f"swap overlap: transfer {service.live.swap_transfer_time:.3f}s"
              f"  exposed {service.live.swap_exposed_time:.3f}s"
              f"  hidden {service.live.swap_hidden_frac():.0%}")
    if router is not None and router.migrations:
        print(f"kv migration: {router.migrations} shipments  "
              f"{router.migrated_blocks} blocks  "
              f"{router.migrated_bytes / 1e6:.1f} MB over the fabric")
    kills = getattr(stats, "kills", None)
    if kills:
        lats = stats.recovery_latencies()
        worst = f"  worst recovery {max(lats):.2f}s" if lats else ""
        print(f"chaos: {len(kills)} kill(s)  re-dispatched "
              f"{stats.redispatched_online} online / "
              f"{stats.redispatched_offline} offline  "
              f"lost {stats.lost_tokens} KV tokens{worst}")
    if getattr(stats, "replica_seconds", 0):
        print(f"fleet cost: {stats.replica_seconds:.1f} replica-seconds")
    sim = getattr(service.backend, "sim", None)
    replicas = sim.replicas if sim is not None else None
    for i, eng in enumerate(service.backend.engines()):
        if replicas is not None:
            rep = replicas[i]
            tag = f"  replica {rep.id} [{rep.state.value:>8}]:"
            rid = rep.id
        else:
            tag, rid = "engine:", i
        line = (f"{tag} hit rate {eng.bm.metrics.hit_rate:.3f}  "
                f"offline hit {eng.bm.metrics.offline_hit_rate:.3f}  "
                f"evictions {eng.bm.metrics.evictions}  "
                f"punished tokens {eng.bm.metrics.punished_tokens}  "
                f"t={eng.now:.1f}s")
        if eng.bm.host is not None:
            line += (f"  host {len(eng.bm.host)}/{eng.bm.host.capacity} blk"
                     f"  swap in/out {eng.bm.metrics.swapped_in_tokens}"
                     f"/{eng.bm.metrics.swapped_out_tokens} tok")
        if eng.bm.metrics.migrated_in_bytes or eng.bm.metrics.migrated_out_bytes:
            line += (f"  migrated in/out "
                     f"{eng.bm.metrics.migrated_in_blocks}"
                     f"/{eng.bm.metrics.migrated_out_blocks} blk")
        if router is not None:
            line += (f"  dispatched {router.per_replica_online.get(rid, 0)}"
                     f"on/{router.per_replica_offline.get(rid, 0)}off")
        if replicas is not None:
            off_tok = sum(r.prompt_len + r.n_output
                          for r in eng.stats.finished if not r.is_online)
            line += f"  offline tok {off_tok}"
        if eng.calibrator is not None:
            line += (f"  calib: refits {eng.calibrator.refits} "
                     f"err {eng.calibrator.mean_rel_err(100):.3f}")
        print(line)


def resolve_policy(args):
    policy = POLICY_BY_NAME[args.policy]
    if args.calibrate:
        policy = dataclasses.replace(policy, calibrate=True,
                                     name=policy.name + "+C")
    return policy


def clock_models(args, *, quadratic_prefill: bool = True,
                 swap_byte: float = None):
    """Ground-truth clocks from --hw-profile/--hw-drift/--hw-jitter; None
    when they match the stock estimate (classic perfect-clock serving)."""
    names = [n.strip() for n in args.hw_profile.split(",") if n.strip()]
    perturbed = args.hw_drift != 1.0 or args.hw_jitter > 0.0
    if names == ["a100"] and not perturbed:
        return None
    out = []
    for i, name in enumerate(names):
        kw = dict(quadratic_prefill=quadratic_prefill,
                  swap_overlap=not args.no_swap_overlap)
        if swap_byte is not None:
            kw["swap_byte"] = swap_byte
        base = TimeModel.preset(name, **kw)
        if perturbed:
            out.append(base.perturbed(scale=args.hw_drift,
                                      jitter=args.hw_jitter,
                                      seed=args.seed + 100 + i))
        else:
            out.append(base)
    return out


def calibrate(model: Model, params, *, chunk_size=64, num_blocks=192,
              block_size=16) -> TimeModel:
    """Fit the Eq.6-8 coefficients by micro-benchmarking the runner (§6)."""
    import time as _t

    from repro.models.paged import PagedRunner
    runner = PagedRunner(model, params, num_blocks, block_size,
                         max_pages_per_seq=num_blocks // 2, chunk_size=chunk_size)
    tm = TimeModel(quadratic_prefill=model.cfg.family not in ("ssm", "hybrid"))
    # prefill samples
    samples = []
    for l in (16, 32, 48, 64):
        toks = list(range(l))
        bt = list(range((l + block_size - 1) // block_size + 1))
        runner.prefill_chunk(toks, 0, bt)                  # warm
        t0 = _t.perf_counter()
        for _ in range(3):
            runner.prefill_chunk(toks, 0, bt)
        samples.append((l, (_t.perf_counter() - t0) / 3))
    tm.fit_prefill(samples)
    # decode samples
    dsamples = []
    for b in (1, 4, 8):
        toks = [1] * b
        bts = [[i] for i in range(b)]
        pos = [0] * b
        runner.decode(toks, bts, pos)
        t0 = _t.perf_counter()
        for _ in range(3):
            runner.decode(toks, bts, pos)
        t = (_t.perf_counter() - t0) / 3
        dsamples.append((1, 1.0, t))
    tm.fit_decode(dsamples)
    return tm


def chaos_config(args):
    """ChaosConfig from --kill-at/--degrade-at specs; None when unused."""
    kills, degrades = [], []
    for spec in args.kill_at or []:
        t, rid = spec.split(":")
        kills.append((float(t), int(rid)))
    for spec in args.degrade_at or []:
        t, rid, factor, dur = spec.split(":")
        degrades.append((float(t), int(rid), float(factor), float(dur)))
    if not kills and not degrades:
        return None
    from repro.cluster import ChaosConfig
    return ChaosConfig(kills=kills, degrades=degrades, seed=args.seed)


def autoscaler_for(args):
    """FleetController from --autoscale/--max-replicas; None when off.
    The capacity figure defaults to an even share of the configured
    fleet-wide arrival rate (override with --rate-per-replica)."""
    if not args.autoscale:
        return None
    from repro.cluster import FleetController
    rate = args.rate_per_replica or args.online_rate / max(args.replicas, 1)
    return FleetController(min_replicas=args.replicas,
                           max_replicas=max(args.max_replicas, args.replicas),
                           rate_per_replica=rate)


def serve_cluster(args) -> None:
    """--replicas N dry-run: co-serve a multi-tenant workload across N
    virtual-clock replicas behind the router and print fleet metrics.
    --online-rate scales the fleet-wide arrival rate across tenants;
    --n-docs/--questions size each tenant's offline corpus. --kill-at/
    --degrade-at inject failures; --autoscale turns on elastic membership."""
    from repro.cluster import ClusterSimulator
    from repro.data import default_tenants, make_multi_tenant_workload

    policy = resolve_policy(args)
    swap_byte = TimeModel.pcie_swap_byte(args.pcie_gbps)
    tm = TimeModel.a100(swap_byte=swap_byte,
                        swap_overlap=not args.no_swap_overlap)
    base = default_tenants(args.tenants)
    scale = args.online_rate / sum(t.online_rate for t in base)
    tenants = tuple(dataclasses.replace(t, online_rate=t.online_rate * scale,
                                        n_docs=args.n_docs,
                                        questions_per_doc=args.questions)
                    for t in base)
    online, offline = make_multi_tenant_workload(
        tenants, args.duration, seed=args.seed)
    sim = ClusterSimulator(args.replicas, policy,
                           router_policy=args.router,
                           num_blocks=args.num_blocks,
                           time_model=tm,
                           clock_models=clock_models(args,
                                                     swap_byte=swap_byte),
                           host_kv_blocks=host_kv_blocks(args),
                           seed=args.seed, chaos=chaos_config(args),
                           autoscaler=autoscaler_for(args))
    service = EchoService(sim, admission=admission_config(args))
    tracer, registry = setup_obs(args, service)
    stats = service.drive(online + offline, until_time=args.duration * 4)

    print(f"policy={policy.name} router={args.router} "
          f"replicas={args.replicas}")
    print_report(service, stats, online, offline)
    write_obs(args, tracer, registry)


def serve_realtime(args) -> None:
    """--serve: put the ``repro.rt`` TCP front door over the engine (or a
    model-free cluster with --replicas>1) and listen until SIGINT/SIGTERM,
    then drain gracefully and report."""
    import asyncio
    import signal

    from repro.rt import AsyncEchoEngine, EchoServer
    from repro.rt.calibrate import calibrate_link

    policy = resolve_policy(args)
    swap_byte = TimeModel.pcie_swap_byte(args.pcie_gbps)
    quad, io, model, params = True, None, None, None
    if args.replicas == 1 and not args.virtual:
        cfg = get_config(args.arch or DEFAULT_ARCH).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        quad = cfg.family not in ("ssm", "hybrid")
        io = io_spec_for_model(model)
    tm = TimeModel.a100(quadratic_prefill=quad, swap_byte=swap_byte,
                        swap_overlap=not args.no_swap_overlap)
    # cold-start link calibration: measure the real host<->device path and
    # refit the swap terms BEFORE the first request is priced against them
    if not args.no_link_calibration:
        print(calibrate_link(tm).summary())
    if args.replicas > 1:
        from repro.cluster import ClusterSimulator
        target = ClusterSimulator(args.replicas, policy,
                                  router_policy=args.router,
                                  num_blocks=args.num_blocks, time_model=tm,
                                  host_kv_blocks=host_kv_blocks(args),
                                  seed=args.seed)
    else:
        target = EchoEngine(model, params, policy,
                            num_blocks=args.num_blocks, block_size=16,
                            chunk_size=64, max_pages_per_seq=32,
                            time_model=tm,
                            host_kv_blocks=host_kv_blocks(args, io),
                            attn_impl=args.attn_impl,
                            kernel_profile=args.kernel_profile)
    rt = AsyncEchoEngine(target, admission=admission_config(args))
    tracer, registry = None, None
    if args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry, Tracer
        tracer = Tracer(cap=args.trace_cap) if args.trace_out else None
        registry = rt.instrument(MetricsRegistry(), tracer)

    async def _serve() -> None:
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except NotImplementedError:      # non-unix event loops
                pass
        await rt.start()
        srv = await EchoServer(rt, host=args.host, port=args.port).start()
        host, port = srv.address
        mode = (f"{args.replicas} virtual replicas" if args.replicas > 1
                else ("virtual engine" if model is None
                      else f"{(args.arch or DEFAULT_ARCH)} (reduced)"))
        print(f"listening on {host}:{port} — {mode}, policy={policy.name}; "
              "newline-delimited JSON, Ctrl-C to drain")
        if args.serve_duration > 0:
            try:
                await asyncio.wait_for(stop.wait(), args.serve_duration)
            except asyncio.TimeoutError:
                pass
        else:
            await stop.wait()
        print("draining (in-flight work finishes, new submits shed)...")
        await srv.close()
        print(f"served {srv.requests_served} requests over "
              f"{srv.connections} connections; "
              f"stats: finished={rt.stats.finished} shed={rt.stats.shed} "
              f"aborted={rt.stats.aborted} steps={rt.stats.steps}")
        leaks = rt.kv_leaks()
        print("kv leaks after drain: "
              + ("none" if not any(leaks.values()) else str(leaks)))

    asyncio.run(_serve())
    write_obs(args, tracer, registry)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None,
                    help=f"model to serve (default {DEFAULT_ARCH}); "
                         "incompatible with --replicas>1 — the cluster "
                         "dry-run is model-free")
    ap.add_argument("--policy", choices=list(POLICY_BY_NAME), default="Echo")
    ap.add_argument("--duration", type=float, default=20.0)
    # the default workload is sized so the offline prefix working set
    # exceeds the device cache under online bursts — the paper's co-serving
    # regime, where preemption and host-tier swaps actually occur (and show
    # up on a --trace-out timeline)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--online-rate", type=float, default=4.0)
    ap.add_argument("--n-docs", type=int, default=12)
    ap.add_argument("--questions", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="N>1: dry-run a virtual N-replica cluster")
    ap.add_argument("--router", default="affinity",
                    choices=("affinity", "round_robin", "random"))
    ap.add_argument("--tenants", type=int, default=3,
                    help="tenant count for the --replicas workload")
    ap.add_argument("--kill-at", action="append", metavar="T:RID",
                    help="chaos: kill replica RID at virtual second T "
                         "(repeatable); its in-flight work is re-dispatched")
    ap.add_argument("--degrade-at", action="append",
                    metavar="T:RID:FACTOR:DUR",
                    help="chaos: slow replica RID's ground-truth clock by "
                         "FACTOR for DUR seconds starting at T (repeatable)")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic fleet: a FleetController adds replicas on "
                         "predicted online load and drains idle ones "
                         "(--replicas is the floor, --max-replicas the cap)")
    ap.add_argument("--max-replicas", type=int, default=4,
                    help="--autoscale ceiling on fleet size")
    ap.add_argument("--rate-per-replica", type=float, default=None,
                    help="--autoscale capacity figure: online req/s one "
                         "replica sustains at the SLO (default: an even "
                         "share of --online-rate)")
    ap.add_argument("--hw-profile", default="a100",
                    help="ground-truth hardware clock preset(s): one of "
                         f"{TimeModel.HW_PROFILES}, comma-separated to cycle "
                         "profiles over a heterogeneous --replicas fleet")
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "ref", "pallas", "splitk"],
                    help="attention kernel path on the real-model runner: "
                         "auto = jnp oracle on CPU / split-K Pallas on "
                         "accelerators (see repro.kernels.ops)")
    ap.add_argument("--kernel-profile", default=None,
                    choices=["a100", "h100", "cpu"],
                    help="kernel block-size tuning table (default: resolve "
                         "from the jax backend)")
    ap.add_argument("--hw-drift", type=float, default=1.0,
                    help="scale the ground-truth clock by this factor "
                         "(2.0 = hardware runs 2x slower than the estimate)")
    ap.add_argument("--hw-jitter", type=float, default=0.0,
                    help="sigma of per-iteration log-normal clock noise")
    ap.add_argument("--calibrate", action="store_true",
                    help="refit the scheduler's time model online from the "
                         "observed clock (§5 closed loop)")
    ap.add_argument("--max-online-queue", type=int, default=None,
                    help="admission control: bound the online queue; "
                         "arrivals beyond it are shed")
    ap.add_argument("--slo-shed-factor", type=float, default=None,
                    help="admission control: shed an online arrival whose "
                         "predicted TTFT exceeds this multiple of its SLO")
    ap.add_argument("--offline-cap", type=int, default=None,
                    help="admission control: soft cap on the offline "
                         "backlog; excess work is deferred, not dropped")
    ap.add_argument("--host-kv-gb", type=float, default=0.5,
                    help="host-memory KV swap tier per replica, in GB: "
                         "evicted blocks with future reuse are parked on "
                         "the host and restored over PCIe instead of "
                         "recomputed (0 or --no-swap = recompute-only)")
    ap.add_argument("--pcie-gbps", type=float, default=25.0,
                    help="effective host<->device bandwidth for the swap "
                         "tier's transfer-time terms (25 ~ PCIe 4.0 x16)")
    ap.add_argument("--no-swap", action="store_true",
                    help="disable the host swap tier even with "
                         "--host-kv-gb set (recompute-only baseline)")
    ap.add_argument("--no-swap-overlap", action="store_true",
                    help="charge PCIe swap traffic serially against every "
                         "iteration instead of overlapping it with compute "
                         "on an async copy stream (the pre-overlap cost "
                         "model; also disables the wall-path double buffer)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(request lifecycle spans + schedule/kernel/swap "
                         "tracks); load the file at https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot: Prometheus text, or a "
                         "structured JSON dump for .json paths")
    ap.add_argument("--trace-cap", type=int, default=200_000,
                    help="trace ring-buffer capacity in events; oldest "
                         "events drop beyond it (bounded memory)")
    ap.add_argument("--serve", action="store_true",
                    help="listen on a TCP socket (repro.rt front door) "
                         "instead of replaying a canned trace; drains "
                         "gracefully on Ctrl-C")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--serve bind address")
    ap.add_argument("--port", type=int, default=8631,
                    help="--serve bind port (0 = ephemeral)")
    ap.add_argument("--virtual", action="store_true",
                    help="--serve the model-free virtual-clock engine "
                         "(protocol/scheduling demos; no jax compute)")
    ap.add_argument("--no-link-calibration", action="store_true",
                    help="skip the cold-start PCIe micro-benchmark that "
                         "refits the swap terms from real jax.device_put "
                         "timings before traffic is admitted")
    ap.add_argument("--serve-duration", type=float, default=0.0,
                    help="auto-drain the --serve listener after this many "
                         "wall seconds (0 = run until signal)")
    args = ap.parse_args()

    if args.serve:
        serve_realtime(args)
        return

    elastic = args.autoscale or args.kill_at or args.degrade_at
    if args.replicas > 1 or elastic:
        if args.arch is not None:
            ap.error("--arch is incompatible with the cluster dry-run "
                     "(--replicas > 1 / --autoscale / --kill-at / "
                     "--degrade-at): it is model-free — drop --arch, or "
                     "drop the fleet flags to serve a real model)")
        serve_cluster(args)
        return

    cfg = get_config(args.arch or DEFAULT_ARCH).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    policy = resolve_policy(args)

    quad = cfg.family not in ("ssm", "hybrid")
    io = io_spec_for_model(model)
    swap_byte = TimeModel.pcie_swap_byte(args.pcie_gbps)
    tm = TimeModel.a100(quadratic_prefill=quad, swap_byte=swap_byte,
                        swap_overlap=not args.no_swap_overlap)
    clocks = clock_models(args, quadratic_prefill=quad, swap_byte=swap_byte)
    if clocks and len(clocks) > 1:
        print(f"warning: --replicas 1 uses only the first --hw-profile "
              f"({args.hw_profile.split(',')[0].strip()}); extra profiles "
              f"are ignored — pass --replicas N for a heterogeneous fleet")
    trace = BurstyTrace(base_rate=args.online_rate, tidal_period=4 * args.duration,
                        seed=args.seed)
    arrivals = trace.sample(0, args.duration)
    online = make_online_requests(arrivals, prompt_mean=64, prompt_std=24,
                                  max_new_mean=16, vocab=cfg.vocab_size,
                                  slo=SLO(1.0, 0.1), seed=args.seed)
    offline = make_offline_corpus(args.n_docs, args.questions, doc_len=160,
                                  question_len=24, max_new=8,
                                  vocab=cfg.vocab_size, seed=args.seed + 1)

    eng = EchoEngine(model, params, policy, num_blocks=args.num_blocks,
                     block_size=16, chunk_size=64,
                     max_pages_per_seq=32, time_model=tm,
                     clock_model=clocks[0] if clocks else None,
                     host_kv_blocks=host_kv_blocks(args, io),
                     attn_impl=args.attn_impl,
                     kernel_profile=args.kernel_profile)
    service = EchoService(eng, admission=admission_config(args))
    tracer, registry = setup_obs(args, service)
    stats = service.drive(online + offline, max_iters=100_000,
                          until_time=args.duration * 4)
    print(f"policy={policy.name}")
    print_report(service, stats, online, offline)
    write_obs(args, tracer, registry)


if __name__ == "__main__":
    main()
