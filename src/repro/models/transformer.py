"""Generic decoder stack: per-family block dispatch + lax.scan over layers.

Layers are grouped into *segments*: a homogeneous (or pattern-repeating)
run scanned with stacked parameters, plus an optional unrolled remainder
(e.g. recurrentgemma's 38 = 12 x (rglru, rglru, attn) + 2 x rglru).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import rms_norm, swiglu, swiglu_init
from repro.models.moe import moe_apply, moe_init


# Unroll switch: the dry-run's roofline probes compile small unrolled stacks
# because XLA cost_analysis counts a while-loop body once (not x trips).
_UNROLL = False


def set_unroll(flag: bool) -> None:
    global _UNROLL
    _UNROLL = flag


def _scan(body, carry, xs, n):
    if not _UNROLL:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ----------------------------------------------------------------- segments
def segments(cfg):
    """Returns list of ('scan', unit, n) / ('unroll', kinds) entries."""
    kinds = cfg.attn_layers
    if cfg.block_pattern:
        unit = tuple(cfg.block_pattern)
        n = len(kinds) // len(unit)
        segs = [("scan", unit, n)]
        rem = kinds[n * len(unit):]
        if rem:
            segs.append(("unroll", tuple(rem), 1))
        return segs
    return [("scan", (kinds[0],), len(kinds))]


# ----------------------------------------------------------------- blocks
def block_init(kind, rng, cfg, dtype):
    r1, r2 = jax.random.split(rng)
    d = cfg.d_model
    if kind == "attn":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": attn_mod.attn_init(r1, cfg, dtype),
                "ln2": jnp.ones((d,), dtype),
                "mlp": swiglu_init(r2, d, cfg.d_ff, dtype)}
    if kind == "moe":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": attn_mod.attn_init(r1, cfg, dtype),
                "ln2": jnp.ones((d,), dtype),
                "moe": moe_init(r2, cfg, dtype)}
    if kind == "ssm":
        return {"ln": jnp.ones((d,), dtype),
                "ssm": ssm_mod.ssm_init(r1, cfg, dtype)}
    if kind == "rglru":
        return {"ln1": jnp.ones((d,), dtype),
                "rglru": rglru_mod.rglru_init(r1, cfg, dtype),
                "ln2": jnp.ones((d,), dtype),
                "mlp": swiglu_init(r2, d, cfg.d_ff, dtype)}
    raise ValueError(kind)


def _attn_window(cfg):
    return cfg.window if cfg.block_pattern else 0


def block_context(kind, p, cfg, x, rope, *, seq_lens=None, return_cache=False):
    cos, sin = rope
    if kind in ("attn", "moe"):
        h, cache = attn_mod.attn_context(
            p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), cos, sin,
            window=_attn_window(cfg), seq_lens=seq_lens, return_cache=return_cache)
        x = x + h
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + (swiglu(p["mlp"], h2) if kind == "attn" else moe_apply(p["moe"], cfg, h2))
        return x, cache
    if kind == "ssm":
        h, cache = ssm_mod.ssm_context(
            p["ssm"], cfg, rms_norm(x, p["ln"], cfg.norm_eps),
            return_cache=return_cache)
        return x + h, cache
    if kind == "rglru":
        h, cache = rglru_mod.rglru_context(
            p["rglru"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
            return_cache=return_cache)
        x = x + h
        x = x + swiglu(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, cache
    raise ValueError(kind)


def block_decode(kind, p, cfg, x, rope, cache, pos):
    cos, sin = rope
    if kind in ("attn", "moe"):
        h, cache = attn_mod.attn_decode(
            p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), cos, sin, cache, pos)
        x = x + h
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + (swiglu(p["mlp"], h2) if kind == "attn" else moe_apply(p["moe"], cfg, h2))
        return x, cache
    if kind == "ssm":
        h, cache = ssm_mod.ssm_decode(p["ssm"], cfg, rms_norm(x, p["ln"], cfg.norm_eps), cache)
        return x + h, cache
    if kind == "rglru":
        h, cache = rglru_mod.rglru_decode(
            p["rglru"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), cache)
        x = x + h
        x = x + swiglu(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, cache
    raise ValueError(kind)


# ----------------------------------------------------------------- stacks
def stack_init(rng, cfg, dtype):
    segs = []
    for si, seg in enumerate(segments(cfg)):
        stype, unit, n = seg
        rng, sub = jax.random.split(rng)
        if stype == "scan":
            rngs = jax.random.split(sub, n)
            stacked = tuple(
                jax.vmap(lambda r, k=kind, i=ki: block_init(
                    k, jax.random.fold_in(r, i), cfg, dtype))(rngs)
                for ki, kind in enumerate(unit))
            segs.append(stacked)
        else:
            rngs = jax.random.split(sub, len(unit))
            segs.append(tuple(block_init(kind, rngs[i], cfg, dtype)
                              for i, kind in enumerate(unit)))
    return segs


def stack_context(params_segs, cfg, x, rope, *, train, seq_lens=None,
                  return_cache=False):
    """Apply all layers in context mode. Returns (x, caches or None)."""
    caches = []
    for seg_def, seg_p in zip(segments(cfg), params_segs):
        stype, unit, n = seg_def
        if stype == "scan":
            def body(h, p_slice, unit=unit):
                outs = []
                for kind, p_k in zip(unit, p_slice):
                    h, c = block_context(kind, p_k, cfg, h, rope,
                                         seq_lens=seq_lens,
                                         return_cache=return_cache)
                    outs.append(c)
                return h, (tuple(outs) if return_cache else None)
            if train:
                body = jax.checkpoint(body)
            x, seg_cache = _scan(body, x, seg_p, n)
        else:
            outs = []
            for kind, p_k in zip(unit, seg_p):
                x, c = block_context(kind, p_k, cfg, x, rope,
                                     seq_lens=seq_lens, return_cache=return_cache)
                outs.append(c)
            seg_cache = tuple(outs) if return_cache else None
        caches.append(seg_cache)
    return x, (caches if return_cache else None)


def stack_decode(params_segs, cfg, x, rope, caches, pos):
    new_caches = []
    for seg_def, seg_p, seg_c in zip(segments(cfg), params_segs, caches):
        stype, unit, n = seg_def
        if stype == "scan":
            def body(h, xs, unit=unit):
                p_slice, c_slice = xs
                outs = []
                for kind, p_k, c_k in zip(unit, p_slice, c_slice):
                    h, c = block_decode(kind, p_k, cfg, h, rope, c_k, pos)
                    outs.append(c)
                return h, tuple(outs)
            x, seg_new = _scan(body, x, (seg_p, seg_c), n)
        else:
            outs = []
            for kind, p_k, c_k in zip(unit, seg_p, seg_c):
                x, c = block_decode(kind, p_k, cfg, x, rope, c_k, pos)
                outs.append(c)
            seg_new = tuple(outs)
        new_caches.append(seg_new)
    return x, new_caches
