"""Logical-axis sharding hook.

Models are mesh-agnostic: they annotate intermediates with *logical* axis
names via ``constrain``. The launcher installs a hook that maps logical
names to mesh axes (divisibility-aware) and applies
``jax.lax.with_sharding_constraint``. Outside pjit the hook is a no-op.
"""
from __future__ import annotations

_HOOK = None


def set_hook(fn) -> None:
    global _HOOK
    _HOOK = fn


def clear_hook() -> None:
    set_hook(None)


def constrain(x, logical_axes):
    """logical_axes: tuple of logical names (or None) per dim of ``x``."""
    if _HOOK is None:
        return x
    return _HOOK(x, logical_axes)
