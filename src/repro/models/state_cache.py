"""State-snapshot serving path for attention-free (SSM) models.

Echo's prefix caching adapted per DESIGN.md §Arch-applicability: instead of
paged KV, the cache pool stores the recurrent state snapshot *after every
block_size tokens* (block_size == cfg.ssm_chunk, so SSD chunk boundaries
line up with BlockManager blocks). A prefix hit resumes from the snapshot
of the last cached block; eviction priorities / threshold / RC apply to
snapshot slots exactly as to KV blocks — the BlockManager is unchanged.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_io import io_spec_for_model
from repro.models import transformer as tfm
from repro.models.common import rms_norm
from repro.models.model import Model
from repro.models.ssm import ssm_context


class StateRunner:
    """Engine runner for recurrent-state configs: pure SSM (mamba2) and
    hybrid (recurrentgemma — RG-LRU states + *bounded* local-attention
    window rings; the full snapshot stays fixed-size, so block-boundary
    snapshotting works identically). Snapshot pool is a host dict
    bid -> state pytree (engine scale is tiny; slots are overwritten when
    the BlockManager reuses a block id, so stale entries are harmless).

    Pure-SSM chunks run through a jitted block-aligned span function (SSD
    chunk scan with boundary capture); hybrid configs step token-by-token
    through decode_step (correct; CPU-test scale)."""

    def __init__(self, model: Model, params, num_blocks: int, block_size: int,
                 max_pages_per_seq: int, chunk_size: int):
        cfg = model.cfg
        kinds = set(cfg.attn_layers)
        if not kinds <= {"ssm", "rglru", "attn"}:
            raise NotImplementedError("StateRunner: ssm/hybrid families only")
        if kinds == {"ssm"}:
            assert block_size == cfg.ssm_chunk, \
                "block_size must equal ssm_chunk so snapshots align with blocks"
        self._pure_ssm = kinds == {"ssm"}
        assert chunk_size % block_size == 0
        self.model = model
        self.params = params
        self.block_size = block_size
        # hybrid: the attention ring must cover the local window
        self._state_len = 1 if self._pure_ssm else max(cfg.window, 1)
        self.io = io_spec_for_model(model)   # state: fixed-size snapshots
        self.pool: Dict[int, object] = {}       # bid -> state pytree (numpy)
        self.live: Dict[int, object] = {}       # rid -> state pytree (jnp)
        # position the live state is valid for: a preempted request can be
        # re-admitted with a LONGER cached prefix than it had computed (the
        # pool gained boundaries meanwhile), making the surviving live
        # state stale for the new resume point — it must only short-circuit
        # the boundary-snapshot resume when the positions agree
        self._live_pos: Dict[int, int] = {}     # rid -> tokens consumed
        self._span_jit = {}
        self._decode_jit = jax.jit(model.decode_step)

    # ------------------------------------------------------------- states
    def _zeros_state(self):
        return self.model.make_cache(1, self._state_len)

    def _span_fn(self, n: int):
        """Jitted: consume n (block-aligned) tokens from a state. Returns
        (last_logits (V,), final_state, boundaries: tuple of states)."""
        if n in self._span_jit:
            return self._span_jit[n]
        model, cfg = self.model, self.model.cfg
        bs = self.block_size
        nc = n // bs

        def span(params, tokens, state):
            h = jnp.take(params["embed"], tokens[None], axis=0)   # (1,n,d)
            new_segs, bound_segs = [], []
            for (stype, unit, cnt), seg_p, seg_s in zip(
                    tfm.segments(cfg), params["layers"], state):

                def body(hh, xs):
                    p_k, st_k = xs
                    out, cache, bounds = ssm_context(
                        p_k["ssm"], cfg,
                        rms_norm(hh, p_k["ln"], cfg.norm_eps),
                        return_cache=True, initial=st_k,
                        boundary_states=True)
                    per_block = tuple(
                        {"conv": bounds["conv"][:, i].astype(cache["conv"].dtype),
                         "ssd": bounds["ssd"][:, i]}
                        for i in range(nc))
                    return hh + out, (cache, per_block)

                if stype == "scan":
                    h, (new_s, bounds) = tfm._scan(body, h,
                                                   (seg_p[0], seg_s[0]), cnt)
                    new_segs.append((new_s,))
                    bound_segs.append((bounds,))
                else:
                    outs, bnds = [], []
                    for p_k, st_k in zip(seg_p, seg_s):
                        h, (c, bd) = body(h, (p_k, st_k))
                        outs.append(c)
                        bnds.append(bd)
                    new_segs.append(tuple(outs))
                    bound_segs.append(tuple(bnds))
            logits = model._logits(params, h[:, -1][:, None])[:, 0]
            # restructure: boundaries[i] has the same pytree shape as state
            boundaries = tuple(
                [tuple(jax.tree.map(lambda t: t, kb[i]) for kb in seg)
                 for seg in bound_segs]
                for i in range(nc))
            return logits[0], new_segs, boundaries

        fn = jax.jit(span)
        self._span_jit[n] = fn
        return fn

    # ------------------------------------------------------------- API
    def prefill_chunk(self, token_chunk: Sequence[int], ctx_len: int,
                      block_table: Sequence[int], rid: Optional[int] = None):
        bs = self.block_size
        assert ctx_len % bs == 0, "resume points are block-aligned"
        if rid in self.live and self._live_pos.get(rid) == ctx_len:
            state = self.live[rid]
        elif ctx_len > 0 and block_table[ctx_len // bs - 1] in self.pool:
            state = jax.tree.map(jnp.asarray,
                                 self.pool[block_table[ctx_len // bs - 1]])
        else:
            assert ctx_len == 0, "resume snapshot missing"
            state = self._zeros_state()

        toks = list(token_chunk)
        full = (len(toks) // bs * bs) if self._pure_ssm else 0
        logits = None
        if full:
            fn = self._span_fn(full)
            logits, state, boundaries = fn(
                self.params, jnp.asarray(toks[:full], jnp.int32), state)
            first_block = ctx_len // bs
            for i, bstate in enumerate(boundaries):
                bid = block_table[first_block + i]
                self.pool[bid] = jax.tree.map(np.asarray, bstate)
        for j, t in enumerate(toks[full:]):
            p = ctx_len + full + j
            lg, state = self._decode_jit(self.params,
                                         jnp.asarray([t], jnp.int32),
                                         state, jnp.asarray([p], jnp.int32))
            logits = lg[0]
            if (p + 1) % bs == 0 and (p + 1) // bs - 1 < len(block_table):
                self.pool[block_table[(p + 1) // bs - 1]] = \
                    jax.tree.map(np.asarray, state)
        self.live[rid] = state
        self._live_pos[rid] = ctx_len + len(toks)
        return np.asarray(logits)

    def decode(self, tokens: Sequence[int], block_tables: List[Sequence[int]],
               pos: Sequence[int], rids: Optional[Sequence[int]] = None):
        bs = self.block_size
        out = np.zeros((len(tokens), self.model.cfg.vocab_size), np.float32)
        for i, (t, bt, p, rid) in enumerate(zip(tokens, block_tables, pos, rids)):
            state = self.live.get(rid)
            if state is None:
                state = self._zeros_state()
            lg, state = self._decode_jit(self.params,
                                         jnp.asarray([t], jnp.int32), state,
                                         jnp.asarray([p], jnp.int32))
            self.live[rid] = state
            self._live_pos[rid] = p + 1
            if (p + 1) % bs == 0 and (p + 1) // bs - 1 < len(bt):
                self.pool[bt[(p + 1) // bs - 1]] = jax.tree.map(np.asarray, state)
            out[i] = np.asarray(lg[0])
        return out

    def release(self, rid: int) -> None:
        self.live.pop(rid, None)
        self._live_pos.pop(rid, None)

    # --------------------------------------------------- host tier protocol
    # Same split-phase block I/O protocol as PagedRunner, over boundary
    # snapshots instead of KV pages. The pool already lives host-side
    # (entries are numpy pytrees, replaced wholesale and never mutated in
    # place), so snapshot/materialize are reference hand-offs, not copies —
    # the copy stream's worker can hold them race-free while the owner
    # thread keeps dispatching compute.
    def snapshot_block(self, bid: int):
        """Phase 1 of a device->host block read: hand out the boundary
        snapshot recorded for ``bid``. Every committed block has one — the
        span function and decode store a snapshot at each crossed boundary,
        and swap-in re-registers restored payloads."""
        snap = self.pool.get(bid)
        assert snap is not None, f"no boundary snapshot for block {bid}"
        return snap

    @staticmethod
    def materialize(snapshot):
        """Phase 2: ensure the snapshot is host numpy. Pool entries already
        are (a no-op tree pass); entries staged device-side by a recent
        ``write_block`` get pulled across here."""
        return jax.tree.map(np.asarray, snapshot)

    def read_block(self, bid: int):
        """Synchronous device->host staging of one boundary snapshot."""
        return self.materialize(self.snapshot_block(bid))

    @staticmethod
    def stage_payload(payload):
        """Host->device upload of one snapshot (the H2D half of swap-in) —
        safe on the copy worker; the pool insert stays with the owner."""
        return jax.tree.map(lambda a: jax.device_put(jnp.asarray(a)), payload)

    def write_block(self, bid: int, payload) -> None:
        """Restore one boundary snapshot device-side: upload (no-op if the
        copy worker already staged it) and re-register under ``bid``. The
        next ``prefill_chunk`` resume from this boundary pays no H2D copy."""
        self.pool[bid] = self.stage_payload(payload)

    def write_block_lazy(self, bid: int, payload) -> None:
        """Re-register a host payload under ``bid`` WITHOUT uploading — the
        ``"in_lazy"`` half of restore_last_only swap-in: earlier boundaries
        of a restored prefix only matter for future mid-prefix resumes, and
        resume lazily uploads (``jnp.asarray``) whatever the pool holds."""
        self.pool[bid] = payload

    def bytes_per_block(self, n_tokens: int) -> int:
        """Link weight of one block: the fixed-size snapshot, regardless of
        how deep the boundary sits in the prefix."""
        return self.io.block_bytes(n_tokens)
