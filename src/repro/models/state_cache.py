"""State-snapshot serving path for attention-free (SSM) models.

Echo's prefix caching adapted per DESIGN.md §Arch-applicability: instead of
paged KV, the cache pool stores the recurrent state snapshot *after every
block_size tokens* (block_size == cfg.ssm_chunk, so SSD chunk boundaries
line up with BlockManager blocks). A prefix hit resumes from the snapshot
of the last cached block; eviction priorities / threshold / RC apply to
snapshot slots exactly as to KV blocks — the BlockManager is unchanged.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.common import rms_norm
from repro.models.model import Model
from repro.models.ssm import ssm_context


class StateRunner:
    """Engine runner for recurrent-state configs: pure SSM (mamba2) and
    hybrid (recurrentgemma — RG-LRU states + *bounded* local-attention
    window rings; the full snapshot stays fixed-size, so block-boundary
    snapshotting works identically). Snapshot pool is a host dict
    bid -> state pytree (engine scale is tiny; slots are overwritten when
    the BlockManager reuses a block id, so stale entries are harmless).

    Pure-SSM chunks run through a jitted block-aligned span function (SSD
    chunk scan with boundary capture); hybrid configs step token-by-token
    through decode_step (correct; CPU-test scale)."""

    def __init__(self, model: Model, params, num_blocks: int, block_size: int,
                 max_pages_per_seq: int, chunk_size: int):
        cfg = model.cfg
        kinds = set(cfg.attn_layers)
        if not kinds <= {"ssm", "rglru", "attn"}:
            raise NotImplementedError("StateRunner: ssm/hybrid families only")
        if kinds == {"ssm"}:
            assert block_size == cfg.ssm_chunk, \
                "block_size must equal ssm_chunk so snapshots align with blocks"
        self._pure_ssm = kinds == {"ssm"}
        assert chunk_size % block_size == 0
        self.model = model
        self.params = params
        self.block_size = block_size
        # hybrid: the attention ring must cover the local window
        self._state_len = 1 if self._pure_ssm else max(cfg.window, 1)
        self.pool: Dict[int, object] = {}       # bid -> state pytree (numpy)
        self.live: Dict[int, object] = {}       # rid -> state pytree (jnp)
        self._span_jit = {}
        self._decode_jit = jax.jit(model.decode_step)

    # ------------------------------------------------------------- states
    def _zeros_state(self):
        return self.model.make_cache(1, self._state_len)

    def _span_fn(self, n: int):
        """Jitted: consume n (block-aligned) tokens from a state. Returns
        (last_logits (V,), final_state, boundaries: tuple of states)."""
        if n in self._span_jit:
            return self._span_jit[n]
        model, cfg = self.model, self.model.cfg
        bs = self.block_size
        nc = n // bs

        def span(params, tokens, state):
            h = jnp.take(params["embed"], tokens[None], axis=0)   # (1,n,d)
            new_segs, bound_segs = [], []
            for (stype, unit, cnt), seg_p, seg_s in zip(
                    tfm.segments(cfg), params["layers"], state):

                def body(hh, xs):
                    p_k, st_k = xs
                    out, cache, bounds = ssm_context(
                        p_k["ssm"], cfg,
                        rms_norm(hh, p_k["ln"], cfg.norm_eps),
                        return_cache=True, initial=st_k,
                        boundary_states=True)
                    per_block = tuple(
                        {"conv": bounds["conv"][:, i].astype(cache["conv"].dtype),
                         "ssd": bounds["ssd"][:, i]}
                        for i in range(nc))
                    return hh + out, (cache, per_block)

                if stype == "scan":
                    h, (new_s, bounds) = tfm._scan(body, h,
                                                   (seg_p[0], seg_s[0]), cnt)
                    new_segs.append((new_s,))
                    bound_segs.append((bounds,))
                else:
                    outs, bnds = [], []
                    for p_k, st_k in zip(seg_p, seg_s):
                        h, (c, bd) = body(h, (p_k, st_k))
                        outs.append(c)
                        bnds.append(bd)
                    new_segs.append(tuple(outs))
                    bound_segs.append(tuple(bnds))
            logits = model._logits(params, h[:, -1][:, None])[:, 0]
            # restructure: boundaries[i] has the same pytree shape as state
            boundaries = tuple(
                [tuple(jax.tree.map(lambda t: t, kb[i]) for kb in seg)
                 for seg in bound_segs]
                for i in range(nc))
            return logits[0], new_segs, boundaries

        fn = jax.jit(span)
        self._span_jit[n] = fn
        return fn

    # ------------------------------------------------------------- API
    def prefill_chunk(self, token_chunk: Sequence[int], ctx_len: int,
                      block_table: Sequence[int], rid: Optional[int] = None):
        bs = self.block_size
        assert ctx_len % bs == 0, "resume points are block-aligned"
        if rid in self.live:
            state = self.live[rid]
        elif ctx_len > 0 and block_table[ctx_len // bs - 1] in self.pool:
            state = jax.tree.map(jnp.asarray,
                                 self.pool[block_table[ctx_len // bs - 1]])
        else:
            assert ctx_len == 0, "resume snapshot missing"
            state = self._zeros_state()

        toks = list(token_chunk)
        full = (len(toks) // bs * bs) if self._pure_ssm else 0
        logits = None
        if full:
            fn = self._span_fn(full)
            logits, state, boundaries = fn(
                self.params, jnp.asarray(toks[:full], jnp.int32), state)
            first_block = ctx_len // bs
            for i, bstate in enumerate(boundaries):
                bid = block_table[first_block + i]
                self.pool[bid] = jax.tree.map(np.asarray, bstate)
        for j, t in enumerate(toks[full:]):
            p = ctx_len + full + j
            lg, state = self._decode_jit(self.params,
                                         jnp.asarray([t], jnp.int32),
                                         state, jnp.asarray([p], jnp.int32))
            logits = lg[0]
            if (p + 1) % bs == 0 and (p + 1) // bs - 1 < len(block_table):
                self.pool[block_table[(p + 1) // bs - 1]] = \
                    jax.tree.map(np.asarray, state)
        self.live[rid] = state
        return np.asarray(logits)

    def decode(self, tokens: Sequence[int], block_tables: List[Sequence[int]],
               pos: Sequence[int], rids: Optional[Sequence[int]] = None):
        bs = self.block_size
        out = np.zeros((len(tokens), self.model.cfg.vocab_size), np.float32)
        for i, (t, bt, p, rid) in enumerate(zip(tokens, block_tables, pos, rids)):
            state = self.live.get(rid)
            if state is None:
                state = self._zeros_state()
            lg, state = self._decode_jit(self.params,
                                         jnp.asarray([t], jnp.int32), state,
                                         jnp.asarray([p], jnp.int32))
            self.live[rid] = state
            if (p + 1) % bs == 0 and (p + 1) // bs - 1 < len(bt):
                self.pool[bt[(p + 1) // bs - 1]] = jax.tree.map(np.asarray, state)
            out[i] = np.asarray(lg[0])
        return out

    def release(self, rid: int) -> None:
        self.live.pop(rid, None)
