"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD scan for train/prefill (quadratic intra-chunk, linear
inter-chunk recurrence) and O(1)-state decode. ngroups=1 (B/C shared
across heads), matching the 1.3B config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.hooks import constrain

NEG_INF = -1e30


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def ssm_init(rng, cfg, dtype):
    """TPU-TP adaptation: the GPU-fused in_proj (one (d, 2*d_inner+2n+nh)
    matmul) is split per consumer layout — x/z/dt shard with heads on the
    `model` axis end-to-end, B/C are computed replicated directly — which
    removes the reshard (collective-permute chains) XLA otherwise inserts
    between the fused projection and the SSD einsums. Identical math."""
    d = cfg.d_model
    d_inner, nheads = ssm_dims(cfg)
    n = cfg.ssm_state
    r1, r2, r3, r4, r5, r6, r7, r8 = jax.random.split(rng, 8)
    return {
        "z_proj": dense_init(r1, (d, d_inner), d, dtype),
        "x_proj": dense_init(r2, (d, d_inner), d, dtype),
        "b_proj": dense_init(r3, (d, n), d, dtype),
        "c_proj": dense_init(r4, (d, n), d, dtype),
        "dt_proj": dense_init(r5, (d, nheads), d, dtype),
        "conv_x": dense_init(r6, (cfg.ssm_conv, d_inner), cfg.ssm_conv, dtype),
        "conv_bc": dense_init(r7, (cfg.ssm_conv, 2 * n), cfg.ssm_conv, dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(r8, (d_inner, d), d_inner, dtype),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T) lower-triangular segment sums (else -inf)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(x, dt_a, b_mat, c_mat, chunk, initial_state=None,
                return_all_states=False):
    """Chunked SSD scan.

    x:    (B, S, H, P)   inputs already scaled by dt
    dt_a: (B, S, H)      A * dt  (negative)
    b/c:  (B, S, N)      shared across heads (ngroups = 1)
    Returns (y (B,S,H,P), final_state (B,H,P,N)). All math fp32.
    """
    bs, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    xc = x.reshape(bs, nc, chunk, h, p).astype(jnp.float32)
    bc = b_mat.reshape(bs, nc, chunk, n).astype(jnp.float32)
    cc = c_mat.reshape(bs, nc, chunk, n).astype(jnp.float32)
    ac = dt_a.reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2).astype(jnp.float32)
    a_cum = jnp.cumsum(ac, axis=-1)                               # (B,H,C,L)

    # intra-chunk (quadratic within chunk)
    el = jnp.exp(_segsum(ac))                                     # (B,H,C,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, el, xc)

    # per-chunk output states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)               # (B,H,C,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    if initial_state is None:
        initial_state = jnp.zeros((bs, 1, h, p, n), jnp.float32)
    else:
        initial_state = initial_state[:, None].astype(jnp.float32)
    states = jnp.concatenate([initial_state, states], axis=1)     # (B,C+1,H,P,N)

    # inter-chunk recurrence
    a_chunk = jnp.pad(a_cum[..., -1], ((0, 0), (0, 0), (1, 0)))   # (B,H,C+1)
    decay_chunk = jnp.exp(_segsum(a_chunk))                       # (B,H,C+1,C+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    state_decay_out = jnp.exp(a_cum)                              # (B,H,C,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(bs, s, h, p)
    if return_all_states:
        return y, final_state, new_states[:, 1:]      # state after each chunk
    return y, final_state


def _split_proj(params, cfg, x):
    z = x @ params["z_proj"]
    xs = x @ params["x_proj"]
    bc = jnp.concatenate([x @ params["b_proj"], x @ params["c_proj"]], axis=-1)
    dt = x @ params["dt_proj"]
    return z, xs, bc, dt


def _postprocess(params, cfg, y, x_in, z):
    d_inner, nheads = ssm_dims(cfg)
    y = y + params["D"][None, None, :, None].astype(jnp.float32) * x_in.astype(jnp.float32)
    y = y.reshape(*y.shape[:-2], d_inner).astype(z.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"]


def _causal_conv(xs, w, b, s):
    k = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + s] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None])


def _causal_conv_with_state(xs, w, b, s, init):
    """init: (B, K, C) raw inputs preceding x (init[:, -1] = newest)."""
    k = w.shape[0]
    pad = jnp.concatenate([init[:, -(k - 1):].astype(xs.dtype), xs], axis=1)
    out = sum(pad[:, i: i + s] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None])


def ssm_context(params, cfg, x, *, return_cache=False, initial=None,
                boundary_states=False):
    """Train / prefill. x: (B,S,d). Cache = final (conv, ssd) states.

    ``initial``: optional {"conv": (B,K,C), "ssd": (B,H,P,N)} resume state
    (Echo's state-snapshot prefix caching for attention-free archs).
    ``boundary_states=True`` additionally returns the SSD state after every
    ssm_chunk boundary (S must then be a chunk multiple) plus the raw conv
    inputs, so the engine can snapshot block-granular states.
    """
    bsz, s, _ = x.shape
    d_inner, nheads = ssm_dims(cfg)
    n = cfg.ssm_state
    z, xs, bc, dt = _split_proj(params, cfg, x)
    k = params["conv_x"].shape[0]
    if initial is not None:
        init_x = initial["conv"][..., :d_inner]
        init_bc = initial["conv"][..., d_inner:]
        conv_x = _causal_conv_with_state(xs, params["conv_x"],
                                         params["conv_x_b"], s, init_x)
        conv_bc = _causal_conv_with_state(bc, params["conv_bc"],
                                          params["conv_bc_b"], s, init_bc)
    else:
        conv_x = _causal_conv(xs, params["conv_x"], params["conv_x_b"], s)
        conv_bc = _causal_conv(bc, params["conv_bc"], params["conv_bc_b"], s)
    x_in = conv_x.reshape(bsz, s, nheads, cfg.ssm_head_dim)
    b_mat = conv_bc[..., :n]
    c_mat = conv_bc[..., n:]
    x_in = constrain(x_in, ("batch", None, "heads", None))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])                            # (H,)
    pad = (-s) % cfg.ssm_chunk
    if pad:
        # dt=0 on padding => decay 1 and zero input: identity on the state
        x_in_p = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_p = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_p = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    else:
        x_in_p, b_p, c_p, dt_p = x_in, b_mat, c_mat, dt
    init_ssd = initial["ssd"] if initial is not None else None
    res = ssd_chunked(
        x_in_p.astype(jnp.float32) * dt_p[..., None], dt_p * a[None, None],
        b_p, c_p, cfg.ssm_chunk, initial_state=init_ssd,
        return_all_states=boundary_states)
    if boundary_states:
        y, final_state, all_states = res
    else:
        y, final_state = res
    if pad:
        y = y[:, :s]
    out = _postprocess(params, cfg, y, x_in, z)
    xbc = jnp.concatenate([xs, bc], axis=-1)              # raw conv inputs
    if initial is not None:
        xbc_full = jnp.concatenate(
            [initial["conv"][:, -(k - 1):].astype(xbc.dtype), xbc], axis=1)
    else:
        xbc_full = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    cache = None
    if return_cache:
        cache = {"conv": xbc_full[:, -k:].astype(x.dtype),
                 "ssd": final_state.astype(jnp.float32)}
    if boundary_states:
        # conv raw-input window ending at each chunk boundary i:
        # xbc_full[:, (i+1)*chunk - 1 : (i+1)*chunk - 1 + k]  (k-1 lead + k..)
        nc = s // cfg.ssm_chunk
        idx = (jnp.arange(1, nc + 1) * cfg.ssm_chunk)[:, None] + \
            jnp.arange(k)[None, :] - 1                     # (nc, K)
        conv_bounds = jnp.take(xbc_full, idx.reshape(-1), axis=1)
        conv_bounds = conv_bounds.reshape(xbc.shape[0], nc, k, -1)
        return out, cache, {"ssd": all_states, "conv": conv_bounds}
    return out, cache


def ssm_decode(params, cfg, x, cache):
    """One-token decode. x: (B,1,d); cache conv (B,K,C), ssd (B,H,P,N)."""
    bsz = x.shape[0]
    d_inner, nheads = ssm_dims(cfg)
    n = cfg.ssm_state
    z, xs, bc, dt = _split_proj(params, cfg, x[:, 0])        # (B, ...)
    xbc = jnp.concatenate([xs, bc], axis=-1)
    conv_state = jnp.concatenate([cache["conv"][:, 1:], xbc[:, None]], axis=1)
    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)
    conv_b = jnp.concatenate([params["conv_x_b"], params["conv_bc_b"]], axis=-1)
    conv = jnp.sum(conv_state * conv_w[None], axis=1) + conv_b[None]
    conv = jax.nn.silu(conv)
    x_in = conv[..., :d_inner].reshape(bsz, nheads, cfg.ssm_head_dim)
    b_mat = conv[..., d_inner: d_inner + n].astype(jnp.float32)
    c_mat = conv[..., d_inner + n:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None])  # (B,H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a[None])                            # (B,H)
    xbar = x_in.astype(jnp.float32) * dt[..., None]          # (B,H,P)
    h_new = (cache["ssd"] * decay[..., None, None]
             + xbar[..., None] * b_mat[:, None, None, :])    # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_mat)             # (B,H,P)
    out = _postprocess(params, cfg, y[:, None], x_in[:, None], z[:, None])
    return out, {"conv": conv_state, "ssd": h_new}
