"""Model facade: init / train forward / prefill / decode over any config."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.common import (default_positions, dtype_of, embed_init,
                                 rms_norm, rope_angles)
from repro.models.hooks import constrain
from repro.models.ssm import ssm_dims
from repro.models.rglru import rglru_width

LONG_THRESHOLD = 1 << 18  # >= 256k context => sliding-window policy kicks in


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg)

    # ------------------------------------------------------------- params
    def init(self, rng):
        cfg = self.cfg
        r_embed, r_stack, r_out, r_mm = jax.random.split(rng, 4)
        params = {
            "embed": embed_init(r_embed, (cfg.vocab_size, cfg.d_model), self.dtype),
            "final_ln": jnp.ones((cfg.d_model,), self.dtype),
            "layers": tfm.stack_init(r_stack, cfg, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(r_out, (cfg.d_model, cfg.vocab_size), self.dtype)
        if cfg.multimodal:
            params["mm_proj"] = embed_init(r_mm, (cfg.mm_embed_dim, cfg.d_model), self.dtype)
        return params

    def param_specs(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------- helpers
    def _embed(self, params, tokens, mm_embeds=None):
        h = jnp.take(params["embed"], tokens, axis=0)
        if mm_embeds is not None and self.cfg.multimodal:
            fused = (mm_embeds.astype(self.dtype) @ params["mm_proj"])
            h = jax.lax.dynamic_update_slice(h, fused, (0, 0, 0))
        return constrain(h, ("batch", None, None))

    def _logits(self, params, h):
        h = rms_norm(h, params["final_ln"], self.cfg.norm_eps)
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = h @ w
        return constrain(logits, ("batch", None, "vocab"))

    def _rope(self, positions):
        cfg = self.cfg
        if cfg.num_heads == 0:          # pure SSM: no rope
            return (None, None)
        return rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                           cfg.mrope_sections)

    def _positions(self, batch, seq, positions, offset=0):
        if positions is not None:
            return positions
        return default_positions(batch, seq, mrope=bool(self.cfg.mrope_sections),
                                 offset=offset)

    # ------------------------------------------------------------- modes
    def forward_train(self, params, tokens, mm_embeds=None, positions=None):
        """tokens (B,S) -> logits (B,S,V)."""
        b, s = tokens.shape
        rope = self._rope(self._positions(b, s, positions))
        h = self._embed(params, tokens, mm_embeds)
        h, _ = tfm.stack_context(params["layers"], self.cfg, h, rope, train=True)
        return self._logits(params, h)

    def prefill(self, params, tokens, mm_embeds=None, seq_lens=None, positions=None):
        """tokens (B,S) -> (last_logits (B,V), cache)."""
        b, s = tokens.shape
        rope = self._rope(self._positions(b, s, positions))
        h = self._embed(params, tokens, mm_embeds)
        h, caches = tfm.stack_context(params["layers"], self.cfg, h, rope,
                                      train=False, seq_lens=seq_lens,
                                      return_cache=True)
        if seq_lens is not None:
            idx = jnp.maximum(seq_lens - 1, 0)
            h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
        else:
            h_last = h[:, -1]
        logits = self._logits(params, h_last[:, None])[:, 0]
        return logits, caches

    def decode_step(self, params, tokens, caches, pos):
        """tokens (B,) int32, pos (B,) int32 -> (logits (B,V), new caches)."""
        b = tokens.shape[0]
        if self.cfg.mrope_sections:
            positions = jnp.broadcast_to(pos[None, :, None], (3, b, 1))
        else:
            positions = pos[:, None]
        rope = self._rope(positions)
        h = self._embed(params, tokens[:, None])
        h, caches = tfm.stack_decode(params["layers"], self.cfg, h, rope, caches, pos)
        return self._logits(params, h)[:, 0], caches

    # ------------------------------------------------------------- caches
    def attn_cache_len(self, total_len: int) -> int:
        cfg = self.cfg
        if cfg.block_pattern:                       # hybrid local attention
            return min(total_len, cfg.window)
        if cfg.long_context == "sliding_window" and total_len >= LONG_THRESHOLD:
            return min(total_len, cfg.sliding_window)
        return total_len

    def _cache_entry(self, kind, batch, total_len, make):
        cfg = self.cfg
        dt = self.dtype
        if kind in ("attn", "moe"):
            s = self.attn_cache_len(total_len)
            shp = (batch, s, cfg.num_kv_heads, cfg.head_dim)
            return {"k": make(shp, dt), "v": make(shp, dt)}
        if kind == "ssm":
            d_inner, nheads = ssm_dims(cfg)
            conv_ch = d_inner + 2 * cfg.ssm_state
            return {"conv": make((batch, cfg.ssm_conv, conv_ch), dt),
                    "ssd": make((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state),
                                jnp.float32)}
        if kind == "rglru":
            w = rglru_width(cfg)
            return {"conv": make((batch, cfg.ssm_conv, w), dt),
                    "h": make((batch, w), jnp.float32)}
        raise ValueError(kind)

    def make_cache(self, batch, total_len, as_specs=False):
        """Cache pytree matching the segment structure (zeros or specs)."""
        make = jax.ShapeDtypeStruct if as_specs else jnp.zeros
        caches = []
        for stype, unit, n in tfm.segments(self.cfg):
            entries = tuple(self._cache_entry(k, batch, total_len, make)
                            for k in unit)
            if stype == "scan":
                if as_specs:
                    entries = jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                        entries)
                else:
                    entries = jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), entries)
            caches.append(entries)
        return caches

    def pad_cache(self, caches, prefill_len, total_len):
        """Convert a prefill cache (seq len = prefill_len) into a decode cache
        sized for ``total_len`` positions, preserving ring-slot semantics."""
        def fix(entry, kind):
            if kind not in ("attn", "moe"):
                return entry
            target = self.attn_cache_len(total_len)

            def remap(arr):
                s_p = arr.shape[-3]
                if s_p <= target:
                    pad = [(0, 0)] * arr.ndim
                    pad[-3] = (0, target - s_p)
                    return jnp.pad(arr, pad)
                # window ring: keep last `target` keys at slots pos % target
                positions = jnp.arange(s_p - target, s_p)
                slots = positions % target
                kept = jnp.take(arr, positions, axis=-3)
                out = jnp.zeros(arr.shape[:-3] + (target,) + arr.shape[-2:], arr.dtype)
                return out.at[..., slots, :, :].set(kept)
            return jax.tree.map(remap, entry)

        out = []
        for (stype, unit, n), seg in zip(tfm.segments(self.cfg), caches):
            out.append(tuple(fix(e, k) for e, k in zip(seg, unit)))
        return out

    def cache_bytes(self, batch, total_len) -> int:
        specs = self.make_cache(batch, total_len, as_specs=True)
        return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(specs))


@functools.lru_cache(maxsize=None)
def _cached_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def get_model(cfg: ModelConfig) -> Model:
    return _cached_model(cfg)
