"""GShard-style capacity-factor routed MoE (top-k, optional shared expert).

Tokens are processed in groups of <=256 so the dispatch/combine tensors stay
O(T * G * top_k) instead of O(T * E * global_capacity). Expert dim shards on
the `model` mesh axis; groups shard on `data`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, swiglu, swiglu_init
from repro.models.hooks import constrain

GROUP = 256


def moe_init(rng, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    rr, r1, r2, r3, rs = jax.random.split(rng, 5)
    p = {
        "router": dense_init(rr, (d, e), d, jnp.float32),
        "we1": dense_init(r1, (e, d, ff), d, dtype),
        "we3": dense_init(r2, (e, d, ff), d, dtype),
        "we2": dense_init(r3, (e, ff, d), ff, dtype),
    }
    if cfg.shared_expert:
        p["shared"] = swiglu_init(rs, d, ff, dtype)
    return p


def _route(gates, top_k, capacity):
    """gates: (n, G, E) fp32 softmax probs.

    Returns dispatch (n,G,E,C) in gates.dtype and combine (n,G,E,C).
    Sequential top-k assignment with per-expert capacity (GShard).
    """
    n, g, e = gates.shape
    remaining = gates
    base = jnp.zeros((n, 1, e), jnp.int32)        # tokens already in each expert
    dispatch = jnp.zeros((n, g, e, capacity), gates.dtype)
    combine = jnp.zeros((n, g, e, capacity), gates.dtype)
    sel_gate_sum = jnp.zeros((n, g, 1), gates.dtype)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                     # (n,G)
        onehot = jax.nn.one_hot(idx, e, dtype=gates.dtype)       # (n,G,E)
        pos = jnp.cumsum(onehot, axis=1).astype(jnp.int32) - 1 + base
        base = base + jnp.sum(onehot, axis=1, keepdims=True).astype(jnp.int32)
        pos_tok = jnp.sum(pos * onehot.astype(jnp.int32), axis=-1)      # (n,G)
        fits = (pos_tok < capacity).astype(gates.dtype)
        slot = jax.nn.one_hot(jnp.minimum(pos_tok, capacity - 1),
                              capacity, dtype=gates.dtype)        # (n,G,C)
        d_k = onehot[..., None] * slot[..., None, :] * fits[..., None, None]
        gate_val = jnp.sum(gates * onehot, axis=-1, keepdims=True)       # (n,G,1)
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_val[..., None]
        sel_gate_sum = sel_gate_sum + gate_val * fits[..., None]
        remaining = remaining * (1.0 - onehot)
    combine = combine / jnp.maximum(sel_gate_sum[..., None], 1e-9)
    return dispatch, combine


def moe_apply(params, cfg, x):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    group = min(t, GROUP)
    pad = (-t) % group
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], axis=0)
    n = xt.shape[0] // group
    xg = xt.reshape(n, group, d)
    xg = constrain(xg, ("batch", None, None))

    logits = (xg.astype(jnp.float32) @ params["router"])         # (n,G,E)
    gates = jax.nn.softmax(logits, axis=-1)
    capacity = max(int(math.ceil(group * cfg.capacity_factor * cfg.top_k
                                 / cfg.num_experts)), 1)
    dispatch, combine = _route(gates, cfg.top_k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, params["we1"]))
    h = h * jnp.einsum("necd,edf->necf", expert_in, params["we3"])
    expert_out = jnp.einsum("necf,efd->necd", h, params["we2"])
    out = jnp.einsum("ngec,necd->ngd", combine, expert_out)

    out = out.reshape(-1, d)
    if pad:
        out = out[:t]
    out = out.reshape(b, s, d)
    if cfg.shared_expert:
        out = out + swiglu(params["shared"], x)
    return out
