"""RecurrentGemma / Griffin RG-LRU recurrent block [arXiv:2402.19427].

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with
a_t = exp(-c * softplus(Lambda) * r_t); gates r/i are per-channel diagonal
projections of the conv output. Prefill uses an associative scan; decode is
a single step. The temporal-mixing branch is gated by a GeLU branch
(Griffin gated recurrent block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

C_FACTOR = 8.0


def rglru_width(cfg):
    return cfg.lru_width or cfg.d_model


def rglru_init(rng, cfg, dtype):
    d = cfg.d_model
    w = rglru_width(cfg)
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    return {
        "wx": dense_init(r1, (d, w), d, dtype),
        "wg": dense_init(r2, (d, w), d, dtype),
        "conv_w": dense_init(r3, (cfg.ssm_conv, w), cfg.ssm_conv, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lam": jnp.ones((w,), jnp.float32) * 2.0,   # softplus(2) ~ 2.1
        "wr": jnp.ones((w,), jnp.float32),
        "br": jnp.zeros((w,), jnp.float32),
        "wi": jnp.ones((w,), jnp.float32),
        "bi": jnp.zeros((w,), jnp.float32),
        "wo": dense_init(r4, (w, d), w, dtype),
    }


def _gates(params, x32):
    r = jax.nn.sigmoid(x32 * params["wr"] + params["br"])
    i = jax.nn.sigmoid(x32 * params["wi"] + params["bi"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    return a, mult * i * x32


def rglru_context(params, cfg, x, *, return_cache=False):
    """Train / prefill. x: (B,S,d) -> (B,S,d); cache = (conv, h) final states."""
    bsz, s, _ = x.shape
    xa = x @ params["wx"]                                    # (B,S,W)
    k = params["conv_w"].shape[0]
    xa_pad = jnp.pad(xa, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xa_pad[:, i: i + s] * params["conv_w"][i][None, None]
               for i in range(k)) + params["conv_b"][None, None]

    a, b = _gates(params, conv.astype(jnp.float32))          # (B,S,W) each

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ params["wg"])
    out = (h.astype(x.dtype) * gate) @ params["wo"]
    cache = None
    if return_cache:
        conv_state = jnp.pad(xa, ((0, 0), (k - 1, 0), (0, 0)))[:, -k:]
        cache = {"conv": conv_state.astype(x.dtype), "h": h[:, -1]}
    return out, cache


def rglru_decode(params, cfg, x, cache):
    """One-token decode. x: (B,1,d); cache conv (B,K,W), h (B,W) fp32."""
    xa = (x[:, 0] @ params["wx"])                            # (B,W)
    conv_state = jnp.concatenate([cache["conv"][:, 1:], xa[:, None]], axis=1)
    conv = jnp.sum(conv_state * params["conv_w"][None], axis=1) + params["conv_b"][None]
    a, b = _gates(params, conv.astype(jnp.float32))
    h = a * cache["h"] + b
    gate = jax.nn.gelu(x[:, 0] @ params["wg"])
    out = (h.astype(x.dtype) * gate) @ params["wo"]
    return out[:, None], {"conv": conv_state, "h": h}
