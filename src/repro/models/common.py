"""Shared model components: norms, RoPE / M-RoPE, SwiGLU, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- init utils
def dense_init(rng, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------- RoPE
def rope_inv_freq(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def rope_angles(positions, head_dim, theta, mrope_sections=()):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE.

    Returns (cos, sin) with shape (B, S, head_dim // 2), float32.
    """
    inv_freq = jnp.asarray(rope_inv_freq(head_dim, theta))       # (hd/2,)
    if positions.ndim == 2:
        ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B,S,hd/2)
    else:
        # M-RoPE: half-dim index i belongs to section s(i); use position stream s.
        assert sum(mrope_sections) == head_dim // 2, "mrope sections must cover head_dim/2"
        sec_id = np.concatenate(
            [np.full(n, i, np.int32) for i, n in enumerate(mrope_sections)]
        )                                                          # (hd/2,)
        pos = positions.astype(jnp.float32)                        # (3,B,S)
        pos_per_dim = pos[sec_id]                                  # (hd/2,B,S)
        ang = jnp.moveaxis(pos_per_dim, 0, -1) * inv_freq          # (B,S,hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, head_dim); cos/sin: (B, S, head_dim//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def default_positions(batch, seq, mrope=False, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if mrope:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


# ---------------------------------------------------------------- MLP
def swiglu_init(rng, d_model, d_ff, dtype):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "w1": dense_init(r1, (d_model, d_ff), d_model, dtype),
        "w3": dense_init(r2, (d_model, d_ff), d_model, dtype),
        "w2": dense_init(r3, (d_ff, d_model), d_ff, dtype),
    }


def swiglu(params, x):
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]
