"""GQA attention: train / prefill / decode, full-causal or sliding-window.

Decode uses a unified ring-buffer cache: the write slot is ``pos % S_cache``
and valid slots are ``min(pos+1, S_cache)``. When ``S_cache`` >= max
position this degenerates to an ordinary append cache; when smaller it is a
sliding window (keys are stored post-RoPE, so slot order is irrelevant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rms_norm
from repro.models.hooks import constrain

NEG_INF = -1e30


def attn_init(rng, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rq, rk, rv, ro = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(rq, (d, hq, hd), d, dtype),
        "wk": dense_init(rk, (d, hkv, hd), d, dtype),
        "wv": dense_init(rv, (d, hkv, hd), d, dtype),
        "wo": dense_init(ro, (hq, hd, d), hq * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(params, cfg, x, cos, sin):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # "seq_fallback": if the head count doesn't divide the model axis
    # (llama4 40H, musicgen 24H ...), shard the query sequence dim instead
    # — sequence-parallel attention — rather than replicating the whole
    # S^2 attention per chip. K/V stay head-sharded when divisible, else
    # replicated (they are the smaller operand; scores/out inherit q's
    # seq sharding).
    q = constrain(q, ("batch", "seq_fallback", "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _grouped_scores(q, k):
    """q (B,S,Hq,hd), k (B,T,Hkv,hd) -> scores (B,Hkv,G,S,T) in fp32."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    return scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))


def _grouped_out(probs, v, dtype):
    """probs (B,Hkv,G,S,T), v (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    b, hkv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(dtype), v)
    return out.reshape(b, s, hkv * g, -1)


# Context attention switches to the blockwise (flash) path above this
# sequence length: never materializes the S^2 score tensor.
FLASH_THRESHOLD = 2048
FLASH_BLOCK = 1024


def _flash_grouped(q, k, v, *, window=0, seq_lens=None, blk=None):
    """Blockwise causal attention (running softmax over KV blocks); the
    XLA-level analogue of kernels/chunked_prefill.py. q (B,S,Hq,hd);
    k/v (B,S,Hkv,hd). Requires S % blk == 0."""
    if blk is None:
        blk = FLASH_BLOCK
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    nb = s // blk
    qg = (q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
          / jnp.sqrt(jnp.asarray(hd, jnp.float32)))
    kb = k.reshape(b, nb, blk, hkv, hd)
    vb = v.reshape(b, nb, blk, hkv, hd)

    i_idx = jnp.arange(s)[:, None]                      # global q positions

    def body(carry, inp):
        m, l, acc = carry
        jblk, k_j, v_j = inp                            # (B,blk,Hkv,hd)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k_j.astype(jnp.float32))        # (B,Hkv,G,S,blk)
        j_idx = jblk * blk + jnp.arange(blk)[None, :]
        mask = j_idx <= i_idx
        if window:
            mask &= (i_idx - j_idx) < window
        if seq_lens is not None:
            mask = mask[None] & (j_idx[None] < seq_lens[:, None, None])
            mask = mask[:, None, None]
        else:
            mask = mask[None, None, None]
        sc = jnp.where(mask, sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m - m_new)                      # (B,Hkv,G,S,1)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        alpha_t = jnp.transpose(alpha, (0, 3, 1, 2, 4))  # (B,S,Hkv,G,1)
        acc = acc * alpha_t + jnp.einsum(
            "bkgst,btkd->bskgd", p, v_j.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, s, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s, 1), jnp.float32)
    acc0 = jnp.zeros((b, s, hkv, g, hd), jnp.float32)
    from repro.models.transformer import _scan
    (m, l, acc), _ = _scan(body, (m0, l0, acc0),
                           (jnp.arange(nb), jnp.moveaxis(kb, 1, 0),
                            jnp.moveaxis(vb, 1, 0)), nb)
    denom = jnp.transpose(l, (0, 3, 1, 2, 4))           # (B,S,Hkv,G,1)
    out = acc / jnp.maximum(denom, 1e-20)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def attn_context(params, cfg, x, cos, sin, *, window=0, seq_lens=None,
                 return_cache=False):
    """Full-context attention (train / prefill). x: (B,S,d)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x, cos, sin)
    if s >= FLASH_THRESHOLD and s % FLASH_BLOCK == 0:
        out = _flash_grouped(q, k, v, window=window, seq_lens=seq_lens)
    else:
        scores = _grouped_scores(q, k)                    # (B,Hkv,G,S,T=S)
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        mask = j <= i
        if window:
            mask &= (i - j) < window
        if seq_lens is not None:                          # right-padding mask
            mask = mask[None] & (j[None] < seq_lens[:, None, None])
            mask = mask[:, None, None]
        else:
            mask = mask[None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _grouped_out(probs, v, x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    cache = {"k": k, "v": v} if return_cache else None
    return out, cache


def attn_decode(params, cfg, x, cos, sin, cache, pos):
    """One-token decode. x: (B,1,d); cache k/v: (B,Sc,Hkv,hd); pos: (B,) int32."""
    b = x.shape[0]
    s_cache = cache["k"].shape[1]
    q, k_new, v_new = _qkv(params, cfg, x, cos, sin)      # seq dim == 1
    slot = pos % s_cache
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    scores = _grouped_scores(q, k)                        # (B,Hkv,G,1,Sc)
    valid = jnp.minimum(pos + 1, s_cache)                 # (B,)
    mask = jnp.arange(s_cache)[None, :] < valid[:, None]  # (B,Sc)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(probs, v, x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, {"k": k, "v": v}
