"""Paged-KV execution path for the serving engine (attention families).

KV lives in a global page pool per layer; requests reference pages through
block tables (the BlockManager owns the indirection). On TPU the attention
inner loops are the Pallas kernels in repro.kernels; on CPU the jnp ref
oracles execute the same layout. Prefill is chunked (Sarathi-style) and
decode is batched — the two batch shapes Echo's scheduler composes.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_io import io_spec_for_model
from repro.kernels import ops as kops
from repro.models import transformer as tfm
from repro.models.common import rms_norm, rope_angles, swiglu
from repro.models.model import Model
from repro.models.moe import moe_apply


def _write_pages(pages, flat_idx, new_k):
    """pages (P,bs,H,hd); flat_idx (N,) into P*bs; entries >= P*bs are
    dropped. NOTE: the drop sentinel must be positive-OOB — JAX scatter
    *wraps* negative indices instead of dropping them."""
    p, bs, h, hd = pages.shape
    flat = pages.reshape(p * bs, h, hd)
    flat = flat.at[flat_idx].set(new_k, mode="drop")
    return flat.reshape(p, bs, h, hd)


def _gather_pages(pages, block_table):
    """pages (P,bs,H,hd); block_table (nblk,) -> (nblk*bs, H, hd)."""
    p, bs, h, hd = pages.shape
    t = block_table.shape[0] * bs
    tok = jnp.arange(t)
    idx = block_table[tok // bs] * bs + tok % bs
    return pages.reshape(p * bs, h, hd)[idx]


def _attn_prefill_paged(p, cfg, x, cos, sin, k_pages, v_pages, block_table,
                        ctx_len, chunk_len, impl="auto", preset=None):
    """x (1,Sc,d). Writes chunk KV into pages, attends vs prefix+chunk."""
    from repro.models.attention import _qkv
    sc = x.shape[1]
    q, k, v = _qkv(p, cfg, x, cos, sin)              # (1,Sc,H*,hd)
    ar = jnp.arange(sc)
    pos = ctx_len + ar
    bs = k_pages.shape[1]
    oob = k_pages.shape[0] * bs                  # positive-OOB drop sentinel
    idx = block_table[pos // bs] * bs + pos % bs
    idx = jnp.where(ar < chunk_len, idx, oob)
    k_pages = _write_pages(k_pages, idx, k[0])
    v_pages = _write_pages(v_pages, idx, v[0])
    kk = _gather_pages(k_pages, block_table)
    vv = _gather_pages(v_pages, block_table)
    out = kops.chunked_prefill_attention(q[0], kk, vv, ctx_len, impl=impl,
                                         preset=preset)
    out = jnp.einsum("shk,hkd->sd", out, p["wo"])[None]
    return out, k_pages, v_pages


def _attn_decode_paged(p, cfg, x, cos, sin, k_pages, v_pages, block_tables,
                       pos, impl="auto", preset=None):
    """x (B,1,d); block_tables (B,nblk); pos (B,). ctx = pos + 1."""
    from repro.models.attention import _qkv
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x, cos, sin)
    bs = k_pages.shape[1]
    oob = k_pages.shape[0] * bs                  # positive-OOB drop sentinel
    bidx = jnp.arange(b)
    safe_pos = jnp.maximum(pos, 0)
    flat_idx = block_tables[bidx, safe_pos // bs] * bs + safe_pos % bs
    flat_idx = jnp.where(pos >= 0, flat_idx, oob)     # padded rows: drop
    k_pages = _write_pages(k_pages, flat_idx, k[:, 0])
    v_pages = _write_pages(v_pages, flat_idx, v[:, 0])
    out = kops.paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                               pos + 1, impl=impl, preset=preset)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return out, k_pages, v_pages


def _block_paged(kind, p, cfg, x, rope, pages, attn_fn):
    cos, sin = rope
    h, kp, vp = attn_fn(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                        cos, sin, pages["k"], pages["v"])
    x = x + h
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + (swiglu(p["mlp"], h2) if kind == "attn"
             else moe_apply(p["moe"], cfg, h2))
    return x, {"k": kp, "v": vp}


class PagedRunner:
    """Owns the page pool and the jitted paged prefill/decode callables."""

    def __init__(self, model: Model, params, num_pages: int, page_size: int,
                 max_pages_per_seq: int, chunk_size: int,
                 attn_impl: str = "auto", kernel_profile: Optional[str] = None):
        cfg = model.cfg
        kinds = set(cfg.attn_layers)
        if not kinds <= {"attn", "moe"}:
            raise NotImplementedError(
                f"paged engine supports attention families, got {kinds}; "
                "SSM/hybrid use state-snapshot caching (see DESIGN.md)")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages = max_pages_per_seq
        self.chunk_size = chunk_size
        # attention kernel dispatch: "auto" runs the jnp oracles on CPU and
        # the split-K Pallas path on accelerators; "ref"/"pallas"/"splitk"
        # force one. kernel_profile picks the block-size tuning table
        # (None resolves by backend — see repro.kernels.ops).
        self.attn_impl = attn_impl
        self.kernel_profile = kernel_profile
        self.tuning = kops.kernel_tuning(kernel_profile)
        self.io = io_spec_for_model(model)   # paged: per-token KV payload
        dt = model.dtype
        shp = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
        self.pages = []
        for stype, unit, n in tfm.segments(cfg):
            seg = tuple({"k": jnp.zeros((n,) + shp, dt),
                         "v": jnp.zeros((n,) + shp, dt)} for _ in unit)
            self.pages.append(seg)
        self._prefill_jit = jax.jit(self._prefill_impl)
        self._decode_jit = jax.jit(self._decode_impl)
        # donate the pool so XLA updates the page in place instead of
        # copying the whole pool per restored block
        self._write_block_jit = jax.jit(self._write_block_impl,
                                        donate_argnums=0)

    # ------------------------------------------------------------- impls
    def _rope_for(self, positions):
        cfg = self.model.cfg
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                           cfg.mrope_sections)

    def _run_stack(self, params, h, rope, pages, attn_fn):
        cfg = self.model.cfg
        new_pages = []
        for (stype, unit, n), seg_p, seg_pg in zip(
                tfm.segments(cfg), params["layers"], pages):
            if stype == "scan":
                def body(x, xs, unit=unit):
                    p_slice, pg_slice = xs
                    outs = []
                    for kind, p_k, pg_k in zip(unit, p_slice, pg_slice):
                        x, pg = _block_paged(kind, p_k, cfg, x, rope, pg_k, attn_fn)
                        outs.append(pg)
                    return x, tuple(outs)
                h, seg_new = jax.lax.scan(body, h, (seg_p, seg_pg))
            else:
                outs = []
                for kind, p_k, pg_k in zip(unit, seg_p, seg_pg):
                    h, pg = _block_paged(kind, p_k, cfg, h, rope, pg_k, attn_fn)
                    outs.append(pg)
                seg_new = tuple(outs)
            new_pages.append(seg_new)
        return h, new_pages

    def _prefill_impl(self, params, tokens, ctx_len, chunk_len, block_table,
                      pages):
        cfg = self.model.cfg
        sc = tokens.shape[0]
        positions = (ctx_len + jnp.arange(sc))[None]                  # (1,Sc)
        rope = self._rope_for(positions)
        h = jnp.take(params["embed"], tokens[None], axis=0)
        attn_fn = (lambda p, c, x, cos, sin, kp, vp: _attn_prefill_paged(
            p, c, x, cos, sin, kp, vp, block_table, ctx_len, chunk_len,
            impl=self.attn_impl, preset=self.kernel_profile))
        h, pages = self._run_stack(params, h, rope, pages, attn_fn)
        idx = jnp.maximum(chunk_len - 1, 0)
        h_last = jax.lax.dynamic_index_in_dim(h[0], idx, 0, keepdims=False)
        logits = self._final_logits(params, h_last[None])
        return logits[0], pages

    def _decode_impl(self, params, tokens, block_tables, pos, pages):
        positions = jnp.maximum(pos, 0)[:, None]
        rope = self._rope_for(positions)
        h = jnp.take(params["embed"], tokens[:, None], axis=0)
        attn_fn = (lambda p, c, x, cos, sin, kp, vp: _attn_decode_paged(
            p, c, x, cos, sin, kp, vp, block_tables, pos,
            impl=self.attn_impl, preset=self.kernel_profile))
        h, pages = self._run_stack(params, h, rope, pages, attn_fn)
        logits = self._final_logits(params, h[:, 0])
        return logits, pages

    def _final_logits(self, params, h):
        cfg = self.model.cfg
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return h @ w

    def release(self, rid: int) -> None:
        """No per-request device state beyond the pages (owned by the
        BlockManager); nothing to drop."""

    # ------------------------------------------------------- host KV swap
    def snapshot_block(self, bid: int):
        """Phase 1 of a device->host block read: dispatch the per-layer page
        slices and return the (possibly still in-flight) device arrays. Must
        run on the thread that owns the pool — dispatch order sequences the
        slice before any later compute or donated scatter overwrites the
        page, so the snapshot always sees the pre-overwrite content."""
        out = []
        for seg in self.pages:
            out.append(tuple({name: pg[name][:, bid] for name in ("k", "v")}
                             for pg in seg))
        return out

    @staticmethod
    def materialize(snapshot):
        """Phase 2: block until the snapshot's slices land and copy them to
        host numpy. Only *reads* self-contained device buffers, so it is
        safe on the async copy worker while the owner thread keeps
        dispatching compute."""
        return [tuple(
            {name: np.asarray(jax.device_get(blk[name]))
             for name in ("k", "v")} for blk in seg)
            for seg in snapshot]

    def read_block(self, bid: int):
        """Device->host staging of one KV page across every layer: the
        swap-out half of the tiered cache (synchronous snapshot +
        materialize). Returns a nested [segment][unit]{"k","v"} structure of
        host numpy arrays, shape (n_layers, page_size, H, hd) each."""
        return self.materialize(self.snapshot_block(bid))

    @staticmethod
    def stage_payload(payload):
        """Host->device upload of a block payload (the H2D half of swap-in)
        without touching the page pool — safe on the copy worker. The cheap
        donated scatter into the pool (``write_block``) stays with the pool
        owner. Idempotent on already-staged device arrays."""
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(jnp.asarray(a)), payload)

    def _write_block_impl(self, pages, bid, payload):
        new_pages = []
        for seg, seg_payload in zip(pages, payload):
            new_seg = []
            for pg, blk in zip(seg, seg_payload):
                new_seg.append({
                    name: pg[name].at[:, bid].set(
                        blk[name].astype(pg[name].dtype))
                    for name in ("k", "v")})
            new_pages.append(tuple(new_seg))
        return new_pages

    def write_block(self, bid: int, payload) -> None:
        """Host->device restore of one KV page (the swap-in half): stages
        the payload via ``jax.device_put`` (a no-op if the copy worker
        already uploaded it) and scatters it into the pool at ``bid`` inside
        a donated jit, so the update happens in place — the block table
        indirection makes the new bid transparent to attention."""
        staged = self.stage_payload(payload)
        self.pages = self._write_block_jit(self.pages, jnp.int32(bid),
                                           staged)

    def write_block_lazy(self, bid: int, payload) -> None:
        """Protocol completeness: paged KV has no lazy restore (attention
        reads every cached position, so every restored page must be device-
        resident) — a lazy write is a full write. The BlockManager never
        journals "in_lazy" for a paged io spec."""
        self.write_block(bid, payload)

    def bytes_per_block(self, n_tokens: int) -> int:
        """Link weight of one block holding ``n_tokens`` (per-token KV)."""
        return self.io.block_bytes(n_tokens)

    # ------------------------------------------------------------- API
    def prefill_chunk(self, token_chunk: Sequence[int], ctx_len: int,
                      block_table: Sequence[int],
                      rid: Optional[int] = None) -> np.ndarray:
        sc = self.chunk_size
        toks = np.zeros((sc,), np.int32)
        toks[: len(token_chunk)] = token_chunk
        bt = np.zeros((self.max_pages,), np.int32)
        bt[: len(block_table)] = block_table
        logits, self.pages = self._prefill_jit(
            self.params, jnp.asarray(toks), jnp.int32(ctx_len),
            jnp.int32(len(token_chunk)), jnp.asarray(bt), self.pages)
        return np.asarray(logits)

    def decode(self, tokens: Sequence[int], block_tables: List[Sequence[int]],
               pos: Sequence[int],
               rids: Optional[Sequence[int]] = None) -> np.ndarray:
        b = len(tokens)
        bpad = 1 << (b - 1).bit_length() if b > 1 else 1
        toks = np.zeros((bpad,), np.int32)
        toks[:b] = tokens
        bts = np.zeros((bpad, self.max_pages), np.int32)
        for i, bt in enumerate(block_tables):
            bts[i, : len(bt)] = bt
        ps = np.full((bpad,), -1, np.int32)   # -1 marks padded rows (no write)
        ps[:b] = pos
        logits, self.pages = self._decode_jit(
            self.params, jnp.asarray(toks), jnp.asarray(bts),
            jnp.asarray(ps), self.pages)
        return np.asarray(logits[:b])
