"""Echo's primary contribution: scheduler + KV manager + estimators."""
from repro.core.block_manager import BlockManager
from repro.core.calibration import CalibrationSample, OnlineCalibrator
from repro.core.engine import EchoEngine, EngineListener, EngineStats
from repro.core.estimator import (MemoryPredictor, PerturbedTimeModel,
                                  RatePredictor, TimeModel)
from repro.core.policies import (ALL_POLICIES, BS, BS_E, BS_E_S, ECHO,
                                 ECHO_C, PolicyConfig)
from repro.core.radix_pool import OfflinePool
from repro.core.request import SLO, Request, RequestState, TaskType
from repro.core.scheduler import Plan, Scheduler

__all__ = [
    "ALL_POLICIES", "BS", "BS_E", "BS_E_S", "ECHO", "ECHO_C",
    "BlockManager", "CalibrationSample", "EchoEngine", "EngineListener",
    "EngineStats",
    "MemoryPredictor", "OfflinePool", "OnlineCalibrator",
    "PerturbedTimeModel", "Plan", "PolicyConfig", "RatePredictor", "Request",
    "RequestState", "SLO", "Scheduler", "TaskType", "TimeModel",
]
