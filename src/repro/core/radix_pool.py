"""Offline request pool: length buckets + block-granular radix tree (§6).

The tree is keyed by block_size-token chunks using the *same chain hash* as
the BlockManager, so node counts directly provide the reference count (RC)
of any cached block: rc(h) = number of pooled offline requests whose prompt
passes through chunk-chain h.

Candidate generation for the scheduler: per length bucket, per top-level
subtree (≈ document group), the FCFS-first request — bounded, but captures
the prefix-sharing structure the KV-aware scheduler exploits.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional

from repro.core.block_manager import chain_hash
from repro.core.request import Request


class _Node:
    __slots__ = ("children", "count")

    def __init__(self):
        self.children: Dict[int, "_Node"] = {}   # chain_hash -> node
        self.count = 0


class OfflinePool:
    def __init__(self, block_size: int, n_buckets: int = 6):
        self.block_size = block_size
        self.n_buckets = n_buckets
        self.buckets: List["OrderedDict[int, Request]"] = \
            [OrderedDict() for _ in range(n_buckets)]
        self.root = _Node()
        self.hash_count: Dict[int, int] = {}     # chain_hash -> passing reqs
        self._chains: Dict[int, List[int]] = {}  # rid -> chain hashes
        self._size = 0

    # ------------------------------------------------------------- basics
    def __len__(self) -> int:
        return self._size

    def __contains__(self, req: Request) -> bool:
        return req.rid in self._chains

    def bucket_of(self, prompt_len: int) -> int:
        """Log2 length buckets starting at 256 tokens: bucket k holds
        prompts in [256*2^k, 256*2^(k+1)), with everything under 512 —
        including sub-256 prompts — in bucket 0 and the last bucket
        open-ended. (A 256-token prompt used to land in bucket 1, stranding
        bucket 0 for sub-256 prompts only, against this doc.)"""
        if prompt_len < 512:
            return 0
        return min(int(math.log2(prompt_len / 256)), self.n_buckets - 1)

    def _chain(self, req: Request) -> List[int]:
        bs = self.block_size
        out, prev = [], 0
        p = req.prompt
        for i in range(len(p) // bs):
            prev = chain_hash(prev, tuple(p[i * bs:(i + 1) * bs]))
            out.append(prev)
        return out

    # ------------------------------------------------------------- add/rm
    def add(self, req: Request) -> None:
        chain = self._chain(req)
        self._chains[req.rid] = chain
        node = self.root
        node.count += 1
        for h in chain:
            node = node.children.setdefault(h, _Node())
            node.count += 1
            self.hash_count[h] = self.hash_count.get(h, 0) + 1
        self.buckets[self.bucket_of(req.prompt_len)][req.rid] = req
        self._size += 1

    def remove(self, req: Request) -> None:
        chain = self._chains.pop(req.rid, None)
        if chain is None:
            return
        # hash_count is decremented for the WHOLE chain (independent of the
        # tree walk — pruning a subtree must not strand deeper counts)
        for h in chain:
            c = self.hash_count.get(h, 0) - 1
            if c <= 0:
                self.hash_count.pop(h, None)
            else:
                self.hash_count[h] = c
        node = self.root
        node.count -= 1
        for h in chain:
            child = node.children.get(h)
            if child is None:
                break
            child.count -= 1
            if child.count <= 0:
                del node.children[h]
                break
            node = child
        self.buckets[self.bucket_of(req.prompt_len)].pop(req.rid, None)
        self._size -= 1

    # ------------------------------------------------------------- queries
    def rc(self, h: int) -> int:
        """Future-reuse count of a cached block hash (paper's RC metadata)."""
        return self.hash_count.get(h, 0)

    def prefix_summary(self) -> Dict[int, int]:
        """Compact radix summary: pooled request count per top-level subtree
        (≈ document group), keyed by first-block chain hash. This is the
        signal a cluster router matches offline work against."""
        return {h: node.count for h, node in self.root.children.items()}

    def group_count(self, h: Optional[int]) -> int:
        """One prefix_summary entry without building the whole dict."""
        node = self.root.children.get(h) if h is not None else None
        return node.count if node is not None else 0

    def group_of(self, req: Request) -> Optional[int]:
        """Top-level subtree key of a pooled request (None if its prompt is
        shorter than one block)."""
        chain = self._chains.get(req.rid)
        return chain[0] if chain else None

    def requests(self) -> Iterable[Request]:
        """All pooled requests, bucket-major insertion order."""
        for bucket in self.buckets:
            yield from bucket.values()

    def fcfs_head(self) -> Optional[Request]:
        best = None
        for bucket in self.buckets:
            for req in bucket.values():
                if best is None or (req.arrival_time, req.rid) < \
                        (best.arrival_time, best.rid):
                    best = req
        return best

    def candidates(self, max_per_bucket: int = 4) -> Iterable[Request]:
        """Representative requests: per bucket, per top-level subtree, the
        FCFS head by (arrival_time, rid) — like ``fcfs_head``. Insertion
        order must not decide: a preempted request is re-``add``-ed at the
        tail of its bucket's OrderedDict, and picking heads by insertion
        order would starve it behind newer arrivals forever.

        Cost: one pass over each bucket plus a sort of the (few) group
        heads — same O(pool) per call as ``fcfs_head``, which the
        non-KV-aware scheduler already pays every iteration."""
        for bucket in self.buckets:
            heads: Dict[int, Request] = {}
            for req in bucket.values():
                chain = self._chains[req.rid]
                group = chain[0] if chain else req.rid
                cur = heads.get(group)
                if cur is None or (req.arrival_time, req.rid) < \
                        (cur.arrival_time, cur.rid):
                    heads[group] = req
            ordered = sorted(heads.values(),
                             key=lambda r: (r.arrival_time, r.rid))
            yield from ordered[:max_per_bucket]

    def peers(self, req: Request, limit: int = 8) -> List[Request]:
        """Requests sharing the longest prefix with ``req`` (batch together)."""
        chain = self._chains.get(req.rid)
        if not chain:
            return []
        node, depth = self.root, 0
        path = []
        for h in chain:
            child = node.children.get(h)
            if child is None:
                break
            path.append(child)
            node = child
        # deepest shared node with count > 1, else top-level group
        target = None
        for nd in reversed(path):
            if nd.count > 1:
                target = nd
                break
        if target is None:
            return []
        out = []
        bucket = self.buckets[self.bucket_of(req.prompt_len)]
        for other in bucket.values():
            if other.rid == req.rid:
                continue
            oc = self._chains[other.rid]
            if len(oc) >= 1 and chain and oc[0] == chain[0]:
                out.append(other)
                if len(out) >= limit:
                    break
        return out
