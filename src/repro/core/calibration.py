"""Online calibration of the §5 execution-time estimator.

The paper fits Eq.6-8 once from offline micro-benchmarks; in a live system
the hardware drifts (MIG neighbours, clock throttling, driver upgrades) and
a fleet is heterogeneous, so the estimate must track the *observed* clock.
The ``OnlineCalibrator`` closes that loop: every engine iteration it records
(prefill spans, decode lengths, observed iteration time), maintains a
sliding window of category-separated samples, tracks the EWMA relative
error of the current estimate, and — when drift persists — refits the
coefficients in place through the estimator's own ``fit_prefill`` /
``fit_decode`` / ``fit_lambda`` routines, so the scheduler's very next plan
is scored with the corrected model.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence, Tuple

from repro.core.estimator import TimeModel

Span = Tuple[int, int]


@dataclass
class CalibrationSample:
    """One engine iteration as seen by the calibrator."""
    t: float
    predicted: float
    observed: float

    @property
    def rel_err(self) -> float:
        return abs(self.predicted - self.observed) / max(self.observed, 1e-12)


class OnlineCalibrator:
    """Drift-triggered refitting of a live ``TimeModel``.

    ``tm`` is mutated in place — it is the same object the scheduler scores
    plans with, so a refit takes effect on the next ``schedule`` call.

    Iterations are bucketed by shape so each Eq.6-8 family gets clean
    samples: prefill-only single-span iterations feed ``fit_prefill`` (the
    span form — mid-context chunks carry the quadratic increment), decode-
    only iterations feed ``fit_decode``, and mixed iterations feed
    ``fit_lambda`` with prefill/decode legs re-estimated by the refit model.
    """

    def __init__(self, tm: TimeModel, *, window: int = 256,
                 ewma_alpha: float = 0.1, drift_threshold: float = 0.15,
                 min_samples: int = 24, cooldown: int = 32,
                 history_limit: Optional[int] = 100_000):
        self.tm = tm
        self.window = window
        self.ewma_alpha = ewma_alpha
        self.drift_threshold = drift_threshold
        self.min_samples = min_samples
        self.cooldown = cooldown

        self._prefill: Deque[Tuple[Span, float]] = deque(maxlen=window)
        self._decode: Deque[Tuple[int, float, float]] = deque(maxlen=window)
        self._mixed: Deque[Tuple[List[Span], List[int], float]] = \
            deque(maxlen=window)
        # swap staging observations: (bytes, seconds) for the PCIe terms,
        # (compute, bytes, total) for the overlap launch overhead — byte-
        # denominated so KV-page and state-snapshot transfers share one pool
        self._swap: Deque[Tuple[int, float]] = deque(maxlen=window)
        self._overlap: Deque[Tuple[float, int, float]] = deque(maxlen=window)
        # inter-node migration observations: (bytes, seconds) for the fabric
        # terms — same byte-denominated shape as the swap pool
        self._migrate: Deque[Tuple[int, float]] = deque(maxlen=window)

        self.ewma_err: Optional[float] = None
        self.ewma_swap_err: Optional[float] = None
        self.ewma_migrate_err: Optional[float] = None
        self.n_observed = 0
        self.n_swap_observed = 0
        self.n_migrate_observed = 0
        self.refits = 0
        self.swap_refits = 0
        self.migrate_refits = 0
        self._since_refit = 0
        self._since_swap_refit = 0
        self._since_migrate_refit = 0
        # bounded so a long-running server cannot grow without limit; the
        # default keeps every benchmark-length run intact
        self.history: Deque[CalibrationSample] = deque(maxlen=history_limit)
        # observability tap: called with ("iter"|"swap", rel_err) per sample
        # so drift probes can histogram residuals live instead of scraping
        # `history` after the run (repro.obs.probes sets this)
        self.on_residual: Optional[Callable[[str, float], None]] = None

    @classmethod
    def passive(cls, tm: TimeModel, **kw) -> "OnlineCalibrator":
        """Measure estimate-vs-clock error but never refit — the static
        baseline of calibration studies."""
        return cls(tm, drift_threshold=float("inf"), **kw)

    # ------------------------------------------------------------- observe
    def observe(self, now: float, prefill_spans: Sequence[Span],
                decode_lens: Sequence[int], observed: float) -> float:
        """Record one iteration; refit on sustained drift. Returns the
        iteration's relative error under the (pre-refit) estimate."""
        spans = [tuple(s) for s in prefill_spans]
        lens = list(decode_lens)
        predicted = self.tm.batch_time(spans, lens)
        sample = CalibrationSample(now, predicted, observed)
        self.history.append(sample)
        self.n_observed += 1
        self._since_refit += 1

        rel = sample.rel_err
        if self.ewma_err is None:
            self.ewma_err = rel
        else:
            self.ewma_err += self.ewma_alpha * (rel - self.ewma_err)

        if spans and not lens:
            if len(spans) == 1:          # unambiguous Eq.6 sample
                self._prefill.append((spans[0], observed))
        elif lens and not spans:
            self._decode.append((max(lens), float(sum(lens)) / len(lens),
                                 observed))
        elif spans and lens:
            self._mixed.append((spans, lens, observed))

        if self.on_residual is not None:
            self.on_residual("iter", rel)
        if self.drifting():
            self.refit()
        return rel

    def observe_swap(self, n_bytes: int, observed: float) -> float:
        """Record one staging transfer of ``n_bytes`` of block payload
        (ROADMAP open item: the swap terms were static after ``fit_swap``
        while the compute terms refit). On the wall path ``observed`` is the
        copy worker's measured staging seconds; on the virtual path the
        ground-truth clock's transfer leg. Refits the PCIe terms in place on
        sustained drift. Returns the transfer's relative error under the
        (pre-refit) estimate."""
        if n_bytes <= 0:
            return 0.0
        predicted = self.tm.swap_time(n_bytes)
        rel = abs(predicted - observed) / max(observed, 1e-12)
        if self.ewma_swap_err is None:
            self.ewma_swap_err = rel
        else:
            self.ewma_swap_err += self.ewma_alpha * (rel - self.ewma_swap_err)
        self._swap.append((n_bytes, observed))
        self.n_swap_observed += 1
        self._since_swap_refit += 1
        if self.on_residual is not None:
            self.on_residual("swap", rel)
        if self.swap_drifting():
            self.refit_swap()
        return rel

    def observe_migration(self, n_bytes: int, observed: float) -> float:
        """Record one replica->replica prefix shipment of ``n_bytes`` —
        the fabric analogue of ``observe_swap``. On the virtual path
        ``observed`` is the ground-truth clock's migration leg. Refits the
        ``migrate_byte``/``migrate_floor`` terms in place on sustained
        drift. Returns the shipment's relative error under the (pre-refit)
        estimate."""
        if n_bytes <= 0:
            return 0.0
        predicted = self.tm.migrate_time(n_bytes)
        rel = abs(predicted - observed) / max(observed, 1e-12)
        if self.ewma_migrate_err is None:
            self.ewma_migrate_err = rel
        else:
            self.ewma_migrate_err += \
                self.ewma_alpha * (rel - self.ewma_migrate_err)
        self._migrate.append((n_bytes, observed))
        self.n_migrate_observed += 1
        self._since_migrate_refit += 1
        if self.on_residual is not None:
            self.on_residual("migrate", rel)
        if self.migrate_drifting():
            self.refit_migration()
        return rel

    def observe_overlap(self, compute: float, n_bytes: int,
                        total: float) -> None:
        """Record one overlapped iteration (compute, transfer bytes, total
        observed time) — the sample family that refits the async launch
        overhead (``fit_swap_overlap``) alongside the PCIe terms."""
        if n_bytes > 0:
            self._overlap.append((compute, n_bytes, total))

    def drifting(self) -> bool:
        return (self.ewma_err is not None
                and self.ewma_err > self.drift_threshold
                and self._since_refit >= self.cooldown
                and self.n_observed >= self.min_samples
                and (len(self._prefill) >= 3 or len(self._decode) >= 3
                     or len(self._mixed) >= 3))

    def swap_drifting(self) -> bool:
        return (self.ewma_swap_err is not None
                and self.ewma_swap_err > self.drift_threshold
                and self._since_swap_refit >= self.cooldown
                and len(self._swap) >= max(self.min_samples // 3, 2))

    def migrate_drifting(self) -> bool:
        return (self.ewma_migrate_err is not None
                and self.ewma_migrate_err > self.drift_threshold
                and self._since_migrate_refit >= self.cooldown
                and len(self._migrate) >= max(self.min_samples // 3, 2))

    # ------------------------------------------------------------- refit
    def _pseudo_prefill(self) -> List[Tuple[Span, float]]:
        """Prefill observations recovered from mixed iterations.

        A busy engine rarely runs prefill-only iterations, so Eq.6 would
        starve on clean samples. For mixed iterations with a single prefill
        chunk, invert Eq.8 around the decode leg (just refit from decode-only
        iterations): whichever branch of max/min the prefill leg lands on,
        solve for it and keep the solution consistent with that branch."""
        out: List[Tuple[Span, float]] = []
        lam = min(max(self.tm.lam, 0.05), 0.95)
        for spans, lens, t in self._mixed:
            if len(spans) != 1:
                continue
            td = self.tm.decode_time(lens)
            tp_hi = (t - (1.0 - lam) * td) / lam       # prefill is the max
            tp_lo = (t - lam * td) / (1.0 - lam)       # prefill is the min
            if tp_hi >= td > 0.0:
                out.append((spans[0], tp_hi))
            elif 0.0 < tp_lo <= td:
                out.append((spans[0], tp_lo))
        return out

    def _scale_correction(self) -> None:
        """Remove residual systematic bias: every Eq.6-8 time coefficient is
        multiplied by the median observed/predicted ratio over the window
        (lambda is unitless and stays). Exact for pure scale drift; a strict
        bias reduction when the categorized fits leave a common-mode error."""
        ratios = []
        for span, t in self._prefill:
            ratios.append(t / max(self.tm.prefill_time([span]), 1e-12))
        for mx, mn, t in self._decode:
            pred = max(self.tm.gamma * mx + self.tm.delta * mn, self.tm.d0)
            ratios.append(t / max(pred, 1e-12))
        for spans, lens, t in self._mixed:
            ratios.append(t / max(self.tm.batch_time(spans, lens), 1e-12))
        if len(ratios) < 3:
            return
        ratios.sort()
        s = ratios[len(ratios) // 2]
        s = min(max(s, 0.1), 10.0)
        for f in ("alpha", "beta", "c", "gamma", "delta", "d0"):
            setattr(self.tm, f, getattr(self.tm, f) * s)

    def refit(self) -> None:
        """Refit every coefficient family with enough window samples.
        Order matters: decode first (clean decode-only samples), then
        prefill (clean + pseudo samples recovered via the new decode leg),
        then lambda with both refit legs."""
        if len(self._decode) >= 3:
            self.tm.fit_decode(list(self._decode))
        # prefill and lambda are coupled through the Eq.8 inversion, so
        # alternate them a few rounds (coordinate descent) per refit
        for _ in range(3):
            prefill = list(self._prefill) + self._pseudo_prefill()
            if len(prefill) >= 3:
                self.tm.fit_prefill(prefill)
            if self._mixed:
                legs = [(self.tm.prefill_time(spans),
                         self.tm.decode_time(lens), t)
                        for spans, lens, t in self._mixed]
                self.tm.fit_lambda(legs)
            if not self._mixed:
                break
        self._scale_correction()
        self.refits += 1
        self._since_refit = 0
        self.ewma_err = None             # measure the refit model afresh
        # age out the pre-drift regime: a refit fires after >= cooldown
        # drifted iterations, so the trailing ``cooldown`` samples of each
        # bucket describe the new hardware; older ones would bias the next
        # fit toward hardware that no longer exists
        for bucket in (self._prefill, self._decode, self._mixed):
            while len(bucket) > self.cooldown:
                bucket.popleft()

    def refit_swap(self) -> None:
        """Refit the PCIe transfer terms (and, given overlap samples, the
        launch overhead) from the observed staging times, through the
        estimator's own fitting routines — the swap analogue of ``refit``."""
        if len(self._swap) >= 2:
            self.tm.fit_swap(list(self._swap))
        if len(self._overlap) >= 2 and self.tm.swap_overlap:
            self.tm.fit_swap_overlap(list(self._overlap))
        self.swap_refits += 1
        self._since_swap_refit = 0
        self.ewma_swap_err = None        # measure the refit terms afresh
        for bucket in (self._swap, self._overlap):
            while len(bucket) > self.cooldown:
                bucket.popleft()

    def refit_migration(self) -> None:
        """Refit the inter-node fabric terms from observed shipment times —
        the migration analogue of ``refit_swap``."""
        if len(self._migrate) >= 2:
            self.tm.fit_migrate(list(self._migrate))
        self.migrate_refits += 1
        self._since_migrate_refit = 0
        self.ewma_migrate_err = None     # measure the refit terms afresh
        while len(self._migrate) > self.cooldown:
            self._migrate.popleft()

    # ------------------------------------------------------------- metrics
    def mean_rel_err(self, last_n: Optional[int] = None) -> float:
        hist = list(self.history)
        if last_n:
            hist = hist[-last_n:]
        if not hist:
            return 0.0
        return sum(s.rel_err for s in hist) / len(hist)

    def convergence_curve(self, every: int = 50) -> List[Tuple[int, float]]:
        """(iteration, mean rel err of the trailing ``every`` iterations) —
        the benchmark's view of how fast calibration converges. Iteration
        numbers are global (offset survives history truncation)."""
        hist = list(self.history)
        start = self.n_observed - len(hist)
        out = []
        for end in range(every, len(hist) + 1, every):
            chunk = hist[end - every:end]
            out.append((start + end,
                        sum(s.rel_err for s in chunk) / len(chunk)))
        return out
