"""Runner-agnostic block I/O economics for the tiered memory layer.

Every runner family that can park blocks on the host tier speaks the same
split-phase protocol (``snapshot_block`` / ``materialize`` /
``stage_payload`` / ``write_block`` — see ``PagedRunner`` and
``StateRunner``), but what a "block" *moves over the link* differs per
family:

  * **paged** (attention KV): a block's payload is per-token KV pages —
    ``n_tokens * bytes_per_token`` — and a restore needs the *whole
    prefix* resident (attention reads every cached position).
  * **state** (SSM / RG-LRU recurrent snapshots): a block's payload is
    one fixed-size state pytree captured at the block boundary, and a
    restore needs only the *last* boundary snapshot uploaded — the
    recurrence resumes from it; earlier boundaries matter only for
    future mid-prefix resumes and land host-side for free
    (``restore_last_only``).

``BlockIOSpec`` captures exactly that: it prices transfers in **bytes**
(the resource the PCIe link actually spends) so the TimeModel, the
scheduler's swap-in-vs-recompute race, eviction punishment, and the
calibrator all charge a state snapshot and a KV page by what they move,
not by a token count that means different things per family.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

KV_BYTES_PER_TOKEN_8B = 131072   # 32 layers x 8 kv-heads x 128 hd x 2(kv) x fp16


@dataclass(frozen=True)
class BlockIOSpec:
    """Byte pricing of one BlockManager block for a runner family."""
    family: str = "paged"                          # "paged" | "state"
    bytes_per_token: int = KV_BYTES_PER_TOKEN_8B   # paged: per-token payload
    block_bytes_fixed: int = 0                     # state: snapshot size
    restore_last_only: bool = False                # state: resume from last

    def block_bytes(self, n_tokens: int) -> int:
        """Bytes one block holding ``n_tokens`` moves when parked (or
        restored individually): the paged payload scales with tokens, the
        state snapshot is fixed-size regardless of the boundary's depth."""
        if n_tokens <= 0:
            return 0
        if self.family == "state":
            return self.block_bytes_fixed
        return self.bytes_per_token * n_tokens

    def restore_bytes(self, n_tokens: int, block_size: int) -> int:
        """Bytes a swap-in of ``n_tokens`` (whole blocks) puts on the link.
        Paged KV uploads every restored page; a ``restore_last_only``
        family uploads one snapshot — the last boundary — and re-registers
        the intermediate payloads host-side without touching the link."""
        if n_tokens <= 0:
            return 0
        if self.family == "state":
            if self.restore_last_only:
                return self.block_bytes_fixed
            n_blocks = (n_tokens + block_size - 1) // block_size
            return n_blocks * self.block_bytes_fixed
        return self.bytes_per_token * n_tokens


def paged_spec(bytes_per_token: int = KV_BYTES_PER_TOKEN_8B) -> BlockIOSpec:
    return BlockIOSpec(family="paged", bytes_per_token=bytes_per_token)


def state_spec(block_bytes: int, *, restore_last_only: bool = True) -> BlockIOSpec:
    return BlockIOSpec(family="state", bytes_per_token=0,
                       block_bytes_fixed=block_bytes,
                       restore_last_only=restore_last_only)


def io_spec_for_model(model) -> BlockIOSpec:
    """Derive the byte spec from a model's architecture (duck-typed on the
    ``Model`` facade: ``cfg``, ``dtype``, ``cache_bytes``). Attention/MoE
    stacks are paged; SSM/RG-LRU/hybrid stacks snapshot one fixed-size
    state pytree per block boundary (the hybrid local-attention window is
    bounded, so the snapshot stays fixed-size too)."""
    cfg = model.cfg
    kinds = set(cfg.attn_layers)
    if kinds <= {"attn", "moe"}:
        itemsize = np.dtype(model.dtype).itemsize
        per_tok = (len(cfg.attn_layers) * cfg.num_kv_heads * cfg.head_dim
                   * 2 * itemsize)                       # k + v
        return paged_spec(per_tok)
    state_len = 1 if kinds == {"ssm"} else max(cfg.window, 1)
    return state_spec(model.cache_bytes(1, state_len))
