"""Task-aware paged KV cache manager (paper §4.2) with a host swap tier.

Block-granular KV cache with hash-based automatic prefix caching (vLLM APC
style) and *priority + LRU* eviction:

  running online tokens        priority = +inf   (ref'd: never evictable)
  preempted online tokens      priority = 1e9
  offline tokens, rc > 0       priority = rc     (future reuse; includes the
                                                  unfinished owner itself)
  finished online tokens       priority = 0.5
  offline tokens, rc == 0      priority = 0

plus a *threshold* capping the blocks held by running requests, reserving
headroom for bursty online arrivals (set by the memory predictor, §5.3).
With ``task_aware=False`` the manager degenerates to vLLM's plain LRU free
table (the BS baseline).

The optional **host tier** (``HostTier``) is a bounded, hash-addressed,
CPU-resident second level: blocks whose priority justifies it (future reuse
rc > 0, or a preempted online owner that will return) are *swapped out* on
eviction instead of dropped, and ``swap_in`` restores a leading prefix over
PCIe instead of recomputing it. The manager only does the bookkeeping and
journals (bid, hash) swap events; the engine stages the actual payloads
against the runner (``drain_swap_events``) and the scheduler decides
swap-in vs. recompute per candidate using the TimeModel's transfer terms.

The manager is runner-family agnostic: a ``BlockIOSpec`` prices what a
block's payload weighs in bytes (paged KV pages scale with tokens; a
recurrent-state snapshot is one fixed-size pytree per boundary), and for
``restore_last_only`` families ``swap_in`` uploads only the last boundary's
snapshot — earlier blocks re-register as ``"in_lazy"`` journal events whose
payload lands host-side without touching the PCIe link.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.block_io import BlockIOSpec, paged_spec
from repro.core.request import Request, TaskType

ONLINE_PREEMPTED_PRIORITY = 1e9
ONLINE_FINISHED_PRIORITY = 0.5
SWAP_MIN_PRIORITY = 1.0       # swap out only blocks with forward reuse


def chain_hash(prev: int, tokens: Tuple[int, ...]) -> int:
    return hash((prev, tokens))


def prefix_chain(tokens: Sequence[int], block_size: int) -> List[int]:
    """Cumulative chain hashes of every full block of ``tokens``. Computed
    once per request and shared across residency probes — the cluster
    router scores one request against every replica, and rehashing the
    same prefix per replica made affinity O(replicas x prompt-blocks)."""
    prev = 0
    out: List[int] = []
    for bi in range(len(tokens) // block_size):
        prev = chain_hash(prev,
                          tuple(tokens[bi * block_size:(bi + 1) * block_size]))
        out.append(prev)
    return out


@dataclass
class Block:
    bid: int
    hash: Optional[int] = None           # set once full & committed
    ref: int = 0
    lat: float = 0.0                     # last access time
    task_type: TaskType = TaskType.OFFLINE
    unfinished_owners: int = 0           # preempted owners that will return
    n_tokens: int = 0                    # valid tokens in this block


@dataclass
class HostBlock:
    """One hash-addressed KV block resident in host memory. ``payload`` is
    the per-layer KV content on the real-runner path (staged by the engine
    via ``PagedRunner.read_block``); None on the virtual path."""
    hash: int
    n_tokens: int
    task_type: TaskType
    unfinished_owners: int = 0
    lat: float = 0.0
    payload: Optional[object] = None
    n_bytes: int = 0                     # link weight per the family's io spec


class HostTier:
    """Bounded host-memory swap space, hash-addressed, priority-evicted.

    Mirrors the device tier's lazy-heap (priority, LAT) eviction order so
    the least valuable host block is dropped first when the tier overflows.
    ``reserve`` slots are kept clear of low-priority (non-preempted-online)
    blocks — the memory predictor sizes this headroom so a predicted online
    burst can always swap its preempted KV out instead of losing it.
    """

    def __init__(self, capacity_blocks: int,
                 priority_of: Optional[Callable[["HostBlock"], float]] = None):
        self.capacity = capacity_blocks
        self.priority_of = priority_of or (lambda hb: 1.0)
        self.blocks: Dict[int, HostBlock] = {}
        self._heap: List[Tuple[float, float, int, int]] = []  # lazy entries
        self._seq = itertools.count()
        self.reserve = 0                 # slots kept free for bursty swaps

    def __len__(self) -> int:
        return len(self.blocks)

    def __contains__(self, h: int) -> bool:
        return h in self.blocks

    def get(self, h: int) -> Optional[HostBlock]:
        return self.blocks.get(h)

    def _push(self, hb: HostBlock) -> None:
        heapq.heappush(self._heap, (self.priority_of(hb), hb.lat,
                                    next(self._seq), hb.hash))

    def _evict_one(self) -> Optional[HostBlock]:
        while self._heap:
            prio, lat, _, h = heapq.heappop(self._heap)
            hb = self.blocks.get(h)
            if hb is None:
                continue                                  # stale entry
            cur = (self.priority_of(hb), hb.lat)
            if (prio, lat) != cur:                        # stale meta: refresh
                self._push(hb)
                continue
            del self.blocks[h]
            return hb
        return None

    def admit(self, hb: HostBlock) -> bool:
        """Insert ``hb``, evicting lower-(priority, LAT) residents if full.
        Returns False when the candidate itself is the least valuable (it
        bounces) or the tier has no capacity. Low-priority candidates may
        only fill ``capacity - reserve`` slots."""
        cap = self.capacity
        if self.priority_of(hb) < ONLINE_PREEMPTED_PRIORITY:
            cap = max(cap - self.reserve, 0)
        if cap <= 0:
            return False
        key = (self.priority_of(hb), hb.lat)
        while len(self.blocks) >= cap:
            victim = self._evict_one()
            if victim is None:
                break
            if (self.priority_of(victim), victim.lat) > key:
                self.blocks[victim.hash] = victim         # keep; hb bounces
                self._push(victim)
                return False
        old = self.blocks.get(hb.hash)
        if old is not None:
            hb.unfinished_owners += old.unfinished_owners
        self.blocks[hb.hash] = hb
        self._push(hb)
        return True

    def pop(self, h: int) -> Optional[HostBlock]:
        return self.blocks.pop(h, None)                   # heap entry lazies


@dataclass
class BlockManagerMetrics:
    hit_blocks: int = 0
    lookup_blocks: int = 0
    offline_hit_blocks: int = 0
    offline_lookup_blocks: int = 0
    evictions: int = 0
    punished_tokens: int = 0             # evicted tokens needed in the future
    swapped_out_blocks: int = 0
    swapped_out_tokens: int = 0
    swapped_out_bytes: int = 0           # PCIe traffic parked to the host
    swapped_in_blocks: int = 0
    swapped_in_tokens: int = 0           # recompute avoided via host tier
    swapped_in_bytes: int = 0            # PCIe traffic restored (lazy = free)
    host_bounced_blocks: int = 0         # refused by the full host tier
    migrated_out_blocks: int = 0         # shipped to another replica
    migrated_out_bytes: int = 0
    migrated_in_blocks: int = 0          # received from another replica
    migrated_in_bytes: int = 0
    migrate_bounced_blocks: int = 0      # arrivals refused by the host tier

    @property
    def hit_rate(self) -> float:
        return self.hit_blocks / self.lookup_blocks if self.lookup_blocks else 0.0

    @property
    def offline_hit_rate(self) -> float:
        """Fig.9's metric: prefix-cache hit ratio of offline prefills."""
        if not self.offline_lookup_blocks:
            return 0.0
        return self.offline_hit_blocks / self.offline_lookup_blocks


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, *,
                 task_aware: bool = True,
                 rc_provider: Optional[Callable[[int], int]] = None,
                 host_blocks: int = 0,
                 io: Optional[BlockIOSpec] = None):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.io = io or paged_spec()
        self.task_aware = task_aware
        self.rc_provider = rc_provider or (lambda h: 0)
        self.blocks: List[Block] = [Block(i) for i in range(num_blocks)]
        self.free: List[int] = list(range(num_blocks))   # never-used / cleared
        self.hash_to_bid: Dict[int, int] = {}
        self._heap: List[Tuple[float, float, int, int]] = []  # lazy entries
        self._seq = itertools.count()
        self.threshold_blocks = num_blocks               # running-KV cap
        self.metrics = BlockManagerMetrics()
        self.host: Optional[HostTier] = (
            HostTier(host_blocks, self._host_priority)
            if host_blocks > 0 else None)
        # journal of ("out"|"in", bid, HostBlock) in decision order; the
        # engine drains it after scheduling, before the runner writes any
        # pages, staging payloads on the journaled HostBlock objects
        self._swap_events: List[Tuple[str, int, HostBlock]] = []

    # ------------------------------------------------------------- stats
    @property
    def running_blocks(self) -> int:
        return sum(1 for b in self.blocks if b.ref > 0)

    @property
    def cached_blocks(self) -> int:
        return sum(1 for b in self.blocks if b.ref == 0 and b.hash is not None)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def usage_breakdown(self) -> Dict[str, int]:
        """For the Fig.10 memory-occupancy benchmark."""
        out = {"running_online": 0, "running_offline": 0,
               "free_online": 0, "free_offline": 0, "unused": len(self.free)}
        for b in self.blocks:
            if b.ref > 0:
                key = "running_online" if b.task_type == TaskType.ONLINE else "running_offline"
                out[key] += 1
            elif b.hash is not None:
                key = "free_online" if b.task_type == TaskType.ONLINE else "free_offline"
                out[key] += 1
        return out

    def occupancy_snapshot(self) -> Dict[str, int]:
        """Gauge-friendly occupancy view for the observability probes:
        device free / running / cached block counts, the §5.3 running-KV
        cap, and the host tier's fill (zero capacity when no tier)."""
        return {
            "free": len(self.free),
            "running": self.running_blocks,
            "cached": self.cached_blocks,
            "threshold": self.threshold_blocks,
            "total": self.num_blocks,
            "host_used": len(self.host) if self.host is not None else 0,
            "host_capacity": (self.host.capacity
                              if self.host is not None else 0),
            "host_reserve": (self.host.reserve
                             if self.host is not None else 0),
        }

    # ------------------------------------------------------------- priority
    def _priority(self, blk: Block) -> float:
        if not self.task_aware:
            return 0.0                                    # pure LRU
        rc = self.rc_provider(blk.hash) + blk.unfinished_owners if blk.hash is not None else 0
        if blk.task_type == TaskType.ONLINE:
            if blk.unfinished_owners:
                return ONLINE_PREEMPTED_PRIORITY
            return ONLINE_FINISHED_PRIORITY
        return float(rc)

    def _host_priority(self, hb: HostBlock) -> float:
        """HostBlock analogue of ``_priority`` (shared rc provider)."""
        rc = self.rc_provider(hb.hash) + hb.unfinished_owners
        if hb.task_type == TaskType.ONLINE:
            if hb.unfinished_owners:
                return ONLINE_PREEMPTED_PRIORITY
            return ONLINE_FINISHED_PRIORITY
        return float(rc)

    def _push_evictable(self, blk: Block) -> None:
        heapq.heappush(self._heap, (self._priority(blk), blk.lat,
                                    next(self._seq), blk.bid))

    # ------------------------------------------------------------- probing
    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Longest cached full-block prefix (in tokens). Read-only."""
        n, prev, cached = 0, 0, 0
        bs = self.block_size
        while n + bs <= len(tokens):
            h = chain_hash(prev, tuple(tokens[n: n + bs]))
            if h not in self.hash_to_bid:
                break
            prev = h
            n += bs
            cached += bs
        return cached

    def device_chain_blocks(self, chain: Sequence[int]) -> int:
        """Leading blocks of a precomputed hash chain resident on device
        (``probe_prefix`` in block units, minus the rehash). Read-only."""
        n = 0
        for h in chain:
            if h not in self.hash_to_bid:
                break
            n += 1
        return n

    def host_chain_blocks(self, chain: Sequence[int],
                          start_block: int) -> int:
        """Blocks of a precomputed chain restorable by swap-in from
        ``start_block``: resident in the host tier but NOT on device
        (``probe_host_prefix`` in block units, minus the rehash)."""
        if self.host is None or not self.host.blocks:
            return 0
        n = 0
        for h in chain[start_block:]:
            if h in self.hash_to_bid or h not in self.host:
                break
            n += 1
        return n

    def probe_host_prefix(self, tokens: Sequence[int], start_tokens: int) -> int:
        """Tokens restorable by swap-in: the longest run of consecutive full
        blocks starting at ``start_tokens`` (block-aligned) that are resident
        in the host tier but NOT on device. Read-only — the scheduler uses
        this to price swap-in vs. recompute before committing."""
        if self.host is None or not self.host.blocks:
            return 0                 # cold tier: skip the chain rehash
        bs = self.block_size
        if start_tokens % bs != 0:
            return 0
        prev = 0
        for bi in range(start_tokens // bs):
            if (bi + 1) * bs > len(tokens):
                return 0
            prev = chain_hash(prev, tuple(tokens[bi * bs:(bi + 1) * bs]))
        n = start_tokens
        restorable = 0
        while n + bs <= len(tokens):
            h = chain_hash(prev, tuple(tokens[n: n + bs]))
            if h in self.hash_to_bid or h not in self.host:
                break
            prev = h
            n += bs
            restorable += bs
        return restorable

    def swap_in(self, req: Request, tokens: Sequence[int], now: float,
                max_tokens: int, *, respect_threshold: bool = True) -> int:
        """Restore up to ``max_tokens`` (whole blocks) of ``req``'s leading
        prefix from the host tier onto device, referencing them to ``req``
        like cache hits. Journals an "in" event per block for the engine to
        stage payloads. Returns the tokens restored (0 on memory pressure).
        The caller advances ``req.computed_tokens`` and charges
        ``TimeModel.swap_time`` — KV becomes resident without compute.
        Restored blocks count against the §4.2 running-KV threshold exactly
        like freshly computed ones (swap-in is not a loophole around the
        burst headroom).

        For a ``restore_last_only`` family (recurrent-state snapshots) only
        the *last* restored boundary's payload must cross the link — the
        recurrence resumes from it — so every earlier event of this call is
        re-journaled as ``"in_lazy"``: the engine re-registers its payload
        with the runner host-side, costing zero transfer time."""
        if self.host is None or max_tokens < self.block_size:
            return 0
        bs = self.block_size
        start = len(req.block_ids) * bs
        prev = self._chain_up_to(req, len(req.block_ids), tokens)
        first_event = len(self._swap_events)
        restored = 0
        while restored + bs <= max_tokens:
            n = start + restored
            if n + bs > len(tokens):
                break
            h = chain_hash(prev, tuple(tokens[n: n + bs]))
            hb = self.host.get(h)
            if hb is None or h in self.hash_to_bid:
                break
            if respect_threshold and self.task_aware and \
                    self.running_blocks + 1 > self.threshold_blocks:
                break
            bid = self._get_free_block()
            if bid is None:
                break
            self.host.pop(h)
            blk = self.blocks[bid]
            blk.hash = h
            blk.ref = 1
            blk.lat = now
            blk.task_type = hb.task_type
            blk.n_tokens = hb.n_tokens
            blk.unfinished_owners = hb.unfinished_owners
            if blk.unfinished_owners > 0:                 # owner came back
                blk.unfinished_owners -= 1
                if h in req.owner_pins:
                    req.owner_pins.remove(h)
            self.hash_to_bid[h] = bid
            req.block_ids.append(bid)
            self._swap_events.append(("in", bid, hb))
            self.metrics.swapped_in_blocks += 1
            self.metrics.swapped_in_tokens += hb.n_tokens
            prev = h
            restored += bs
        if restored and self.io.restore_last_only:
            for i in range(first_event, len(self._swap_events) - 1):
                kind, bid, hb = self._swap_events[i]
                if kind == "in":
                    self._swap_events[i] = ("in_lazy", bid, hb)
        for kind, _, hb in self._swap_events[first_event:]:
            if kind == "in":
                self.metrics.swapped_in_bytes += hb.n_bytes
        return restored

    def pending_swap_out_tokens(self) -> int:
        """Undrained swap-OUT traffic journaled by the current scheduling
        pass — the estimator charges it against the SLO budget alongside
        planned swap-ins, since the engine will clock both directions."""
        return sum(hb.n_tokens for kind, _, hb in self._swap_events
                   if kind == "out")

    def pending_swap_out_bytes(self) -> int:
        """``pending_swap_out_tokens`` in link units — what the journaled
        swap-OUTs will actually put on the PCIe link, per the family's io
        spec (bytes are priced at eviction time into ``HostBlock.n_bytes``)."""
        return sum(hb.n_bytes for kind, _, hb in self._swap_events
                   if kind == "out")

    def drain_swap_events(self) -> List[Tuple[str, int, HostBlock]]:
        """Swap decisions since the last drain, in order. The engine must
        process these before the runner writes any pages this iteration —
        an "out" bid's device pages are still intact until then, and an
        "in" whose block was swapped out this same iteration reads the
        payload staged by its earlier "out" entry (same HostBlock object).
        "in_lazy" entries (restore_last_only families) re-register the host
        payload with the runner without an upload — zero link traffic."""
        out, self._swap_events = self._swap_events, []
        return out

    # ------------------------------------------------------------ migration
    def export_block(self, h: int,
                     payload_reader: Optional[Callable[[int], object]] = None
                     ) -> Optional[HostBlock]:
        """Pull block ``h`` out of this manager as a ``HostBlock`` ready to
        ship to another replica — the source side of cross-replica KV
        migration. A host-tier copy is popped directly; an idle (ref == 0)
        device copy is materialized through ``payload_reader`` (the runner's
        ``read_block`` on the real path) and its device slot freed. Returns
        None — and exports nothing — when the hash is absent from both tiers
        or the device copy is still referenced."""
        if self.host is not None:
            hb = self.host.pop(h)
            if hb is not None:
                self.metrics.migrated_out_blocks += 1
                self.metrics.migrated_out_bytes += hb.n_bytes
                return hb
        bid = self.hash_to_bid.get(h)
        if bid is None:
            return None
        blk = self.blocks[bid]
        if blk.ref > 0:
            return None
        hb = HostBlock(hash=h, n_tokens=blk.n_tokens,
                       task_type=blk.task_type,
                       unfinished_owners=blk.unfinished_owners,
                       lat=blk.lat,
                       payload=(payload_reader(bid)
                                if payload_reader is not None else None),
                       n_bytes=self.io.block_bytes(blk.n_tokens))
        del self.hash_to_bid[h]
        blk.hash = None
        blk.unfinished_owners = 0
        blk.n_tokens = 0
        self.free.append(bid)            # stale heap entries skip hash=None
        self.metrics.migrated_out_blocks += 1
        self.metrics.migrated_out_bytes += hb.n_bytes
        return hb

    def import_host_block(self, hb: HostBlock, now: float) -> bool:
        """Land a migrated ``HostBlock`` in this manager's host tier — the
        destination side of cross-replica KV migration. The block becomes
        restorable by the ordinary ``swap_in`` path (it is indistinguishable
        from a locally parked prefix). Returns False when the hash is
        already resident on either tier (no bytes moved) or the host tier
        refuses it (full of more valuable blocks, or absent)."""
        if hb.hash in self.hash_to_bid:
            return False
        if self.host is None:
            self.metrics.migrate_bounced_blocks += 1
            return False
        if hb.hash in self.host:
            return False
        hb.lat = now
        if not self.host.admit(hb):
            self.metrics.migrate_bounced_blocks += 1
            return False
        self.metrics.migrated_in_blocks += 1
        self.metrics.migrated_in_bytes += hb.n_bytes
        return True

    def release_owner_pins(self, req: Request) -> None:
        """Drop the unfinished-owner pins an aborted request left on blocks
        it no longer references (committed blocks released at preemption
        carry ``unfinished_owners`` for the owner's return — an aborted
        owner never returns). Covers both tiers; the lazy heaps re-rank the
        blocks on their next pop.

        Pins are resolved by content hash, matching the rest of the owner
        accounting (an ``allocate`` hit by ANY same-content request already
        counts as "the owner came back"): if this request's pinned hash was
        dropped and later re-pinned by a different request, the release may
        discharge that pin instead — a priority imprecision, never a
        correctness issue."""
        for h in req.owner_pins:
            bid = self.hash_to_bid.get(h)
            if bid is not None:
                blk = self.blocks[bid]
                if blk.unfinished_owners > 0:
                    blk.unfinished_owners -= 1
                continue
            hb = self.host.get(h) if self.host is not None else None
            if hb is not None and hb.unfinished_owners > 0:
                hb.unfinished_owners -= 1
        req.owner_pins.clear()

    def evictable_count(self) -> int:
        return sum(1 for b in self.blocks if b.ref == 0 and b.hash is not None)

    def clean_evictable_count(self) -> int:
        """Evictable blocks whose eviction carries no punishment (priority
        < 1: dead offline, finished online) — plus never-used free blocks."""
        n = len(self.free)
        for b in self.blocks:
            if b.ref == 0 and b.hash is not None and self._priority(b) < 1.0:
                n += 1
        return n

    def can_allocate(self, n_new: int, *, respect_threshold: bool = True) -> bool:
        if len(self.free) + self.evictable_count() < n_new:
            return False
        if respect_threshold and self.task_aware:
            if self.running_blocks + n_new > self.threshold_blocks:
                return False
        return True

    # ------------------------------------------------------------- eviction
    def would_swap(self, priority: float) -> bool:
        """Swap-out policy: a block is worth the PCIe round trip only when
        someone will come back for it — rc > 0 offline (future prefix reuse)
        or a preempted online owner. Dead offline / finished online blocks
        are dropped for free exactly as before."""
        return self.host is not None and priority >= SWAP_MIN_PRIORITY

    def _evict_one(self) -> Optional[int]:
        while self._heap:
            prio, lat, _, bid = heapq.heappop(self._heap)
            blk = self.blocks[bid]
            if blk.ref > 0 or blk.hash is None:
                continue                                  # stale entry
            cur = (self._priority(blk), blk.lat)
            if (prio, lat) != cur:                        # stale meta: refresh
                self._push_evictable(blk)
                continue
            # evict — swapping to the host tier if the block has a future
            rc = self.rc_provider(blk.hash) + blk.unfinished_owners
            swapped = False
            if rc > 0 and self.would_swap(prio):
                hb = HostBlock(hash=blk.hash, n_tokens=blk.n_tokens,
                               task_type=blk.task_type,
                               unfinished_owners=blk.unfinished_owners,
                               lat=blk.lat,
                               n_bytes=self.io.block_bytes(blk.n_tokens))
                swapped = self.host.admit(hb)
                if swapped:
                    self._swap_events.append(("out", bid, hb))
                    self.metrics.swapped_out_blocks += 1
                    self.metrics.swapped_out_tokens += blk.n_tokens
                    self.metrics.swapped_out_bytes += hb.n_bytes
                else:
                    self.metrics.host_bounced_blocks += 1
            if rc > 0 and not swapped:
                self.metrics.punished_tokens += blk.n_tokens
            del self.hash_to_bid[blk.hash]
            blk.hash = None
            blk.unfinished_owners = 0
            blk.n_tokens = 0
            self.metrics.evictions += 1
            return bid
        return None

    def peek_eviction_order(self, n: int) -> List[Block]:
        """The next ``n`` blocks ``_evict_one`` would realize, WITHOUT
        mutating anything — the single source of truth for the scheduler's
        expected-punishment peek (previously an independent sort that could
        disagree with the heap's realized order). Replays the lazy-heap
        discipline against a copy: stale entries are skipped/refreshed
        exactly as eviction would."""
        if n <= 0:
            return []
        heap = list(self._heap)
        heapq.heapify(heap)
        seen: set = set()
        out: List[Block] = []
        while heap and len(out) < n:
            prio, lat, _, bid = heapq.heappop(heap)
            blk = self.blocks[bid]
            if blk.ref > 0 or blk.hash is None or bid in seen:
                continue
            if (prio, lat) != (self._priority(blk), blk.lat):
                heapq.heappush(heap, (self._priority(blk), blk.lat,
                                      next(self._seq), bid))
                continue
            seen.add(bid)
            out.append(blk)
        return out

    def _get_free_block(self) -> Optional[int]:
        if self.free:
            return self.free.pop()
        return self._evict_one()

    # ------------------------------------------------------------- alloc
    def allocate(self, req: Request, target_len: int, tokens: Sequence[int],
                 now: float, *, respect_threshold: bool = True) -> Optional[int]:
        """Ensure ``req`` owns blocks covering ``target_len`` token slots.

        ``tokens`` is the known token content (prompt + generated so far);
        full blocks within it are prefix-matched against the cache.
        Returns the number of *leading consecutive cache-hit tokens* among
        the newly covered blocks (0 if none), or None if memory is
        insufficient (partial-progress refs rolled back).
        """
        bs = self.block_size
        have = len(req.block_ids)
        need_blocks = (target_len + bs - 1) // bs
        if need_blocks <= have:
            return 0
        newly = []
        leading_hits = 0
        leading = True
        prev = self._chain_up_to(req, have, tokens)
        ok = True
        matching = True                  # only a *leading* prefix may hit
        for bi in range(have, need_blocks):
            start = bi * bs
            full = start + bs <= len(tokens)
            h = (chain_hash(prev, tuple(tokens[start: start + bs]))
                 if (full and matching) else None)
            offline = req.task_type == TaskType.OFFLINE
            if full:
                self.metrics.lookup_blocks += 1
                if offline:
                    self.metrics.offline_lookup_blocks += 1
            if h is not None and h in self.hash_to_bid:
                bid = self.hash_to_bid[h]
                blk = self.blocks[bid]
                blk.ref += 1
                blk.lat = now
                if blk.unfinished_owners > 0:
                    blk.unfinished_owners -= 1            # owner came back
                    if h in req.owner_pins:
                        req.owner_pins.remove(h)
                self.metrics.hit_blocks += 1
                if offline:
                    self.metrics.offline_hit_blocks += 1
                prev = h
                if leading:
                    leading_hits += bs
            else:
                matching = False
                leading = False
                if respect_threshold and self.task_aware and \
                        self.running_blocks + 1 > self.threshold_blocks:
                    ok = False
                bid = self._get_free_block() if ok else None
                if bid is None:
                    ok = False
                    break
                blk = self.blocks[bid]
                blk.ref = 1
                blk.lat = now
                blk.task_type = req.task_type
                blk.hash = None
                blk.n_tokens = 0
            newly.append(bid)
            req.block_ids.append(bid)
        if not ok:
            for bid in newly:
                self._release_block(bid, now)
                req.block_ids.pop()
            return None
        return leading_hits

    def _chain_up_to(self, req: Request, n_blocks: int, tokens: Sequence[int]) -> int:
        prev = 0
        bs = self.block_size
        for bi in range(n_blocks):
            if (bi + 1) * bs <= len(tokens):
                prev = chain_hash(prev, tuple(tokens[bi * bs: (bi + 1) * bs]))
        return prev

    def commit(self, req: Request, tokens: Sequence[int], now: float) -> None:
        """Register hashes for req's now-full computed blocks (content known)."""
        bs = self.block_size
        prev = 0
        covered = min(len(tokens), req.total_len)
        n_full = covered // bs
        # track valid tokens in the trailing partial block (for punishment).
        # The slot can alias a COMMITTED full block (a deeper-prefix peer's
        # block hash-hit at allocate): its content — and the payload an
        # eviction would move — is still the full block; don't relabel it.
        if n_full < len(req.block_ids) and covered % bs:
            blk = self.blocks[req.block_ids[n_full]]
            if blk.hash is None:
                blk.n_tokens = covered % bs
        for bi in range(n_full):
            chunk = tuple(tokens[bi * bs: (bi + 1) * bs])
            h = chain_hash(prev, chunk)
            prev = h
            if bi >= len(req.block_ids):
                break
            blk = self.blocks[req.block_ids[bi]]
            blk.lat = now
            blk.n_tokens = bs
            if blk.hash is None and h not in self.hash_to_bid:
                blk.hash = h
                blk.task_type = req.task_type if blk.ref <= 1 else blk.task_type
                self.hash_to_bid[h] = blk.bid
                if self.host is not None:
                    # the content was recomputed rather than swapped back:
                    # the host copy is now redundant — absorb it so the
                    # tiers stay disjoint, moving its owner pins onto the
                    # (fresher) device block
                    hb = self.host.pop(h)
                    if hb is not None:
                        blk.unfinished_owners += hb.unfinished_owners

    # ------------------------------------------------------------- free
    def _release_block(self, bid: int, now: float,
                       unfinished: bool = False) -> Optional[int]:
        """Returns the block's hash iff this release pinned an
        unfinished-owner on it (so the owner can track — and on abort
        release — its pins)."""
        blk = self.blocks[bid]
        blk.ref -= 1
        blk.lat = now
        if blk.ref == 0:
            if unfinished:
                blk.unfinished_owners += 1
            if blk.hash is None:
                if unfinished:                            # lost work: re-prefill
                    self.metrics.punished_tokens += blk.n_tokens
                blk.n_tokens = 0
                blk.unfinished_owners = 0
                self.free.append(bid)                     # uncommitted: discard
            else:
                self._push_evictable(blk)
                if unfinished:
                    return blk.hash
        return None

    def free_request(self, req: Request, now: float, *, finished: bool) -> None:
        for bid in req.block_ids:
            pinned = self._release_block(bid, now, unfinished=not finished)
            if pinned is not None:
                req.owner_pins.append(pinned)
        req.block_ids.clear()

    def trim_request(self, req: Request, keep_tokens: int, now: float) -> None:
        """Release blocks beyond the ``keep_tokens`` boundary — allocated for
        a planned chunk that was then shed before computing anything, so no
        work is lost: fresh blocks return to the free list, cache-hit blocks
        just drop the extra reference and stay cached."""
        keep = (keep_tokens + self.block_size - 1) // self.block_size
        while len(req.block_ids) > keep:
            self._release_block(req.block_ids.pop(), now)

    def touch(self, req: Request, now: float) -> None:
        for bid in req.block_ids:
            self.blocks[bid].lat = now
