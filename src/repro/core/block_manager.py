"""Task-aware paged KV cache manager (paper §4.2).

Block-granular KV cache with hash-based automatic prefix caching (vLLM APC
style) and *priority + LRU* eviction:

  running online tokens        priority = +inf   (ref'd: never evictable)
  preempted online tokens      priority = 1e9
  offline tokens, rc > 0       priority = rc     (future reuse; includes the
                                                  unfinished owner itself)
  finished online tokens       priority = 0.5
  offline tokens, rc == 0      priority = 0

plus a *threshold* capping the blocks held by running requests, reserving
headroom for bursty online arrivals (set by the memory predictor, §5.3).
With ``task_aware=False`` the manager degenerates to vLLM's plain LRU free
table (the BS baseline).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request, TaskType

ONLINE_PREEMPTED_PRIORITY = 1e9
ONLINE_FINISHED_PRIORITY = 0.5


def chain_hash(prev: int, tokens: Tuple[int, ...]) -> int:
    return hash((prev, tokens))


@dataclass
class Block:
    bid: int
    hash: Optional[int] = None           # set once full & committed
    ref: int = 0
    lat: float = 0.0                     # last access time
    task_type: TaskType = TaskType.OFFLINE
    unfinished_owners: int = 0           # preempted owners that will return
    n_tokens: int = 0                    # valid tokens in this block


@dataclass
class BlockManagerMetrics:
    hit_blocks: int = 0
    lookup_blocks: int = 0
    offline_hit_blocks: int = 0
    offline_lookup_blocks: int = 0
    evictions: int = 0
    punished_tokens: int = 0             # evicted tokens needed in the future

    @property
    def hit_rate(self) -> float:
        return self.hit_blocks / self.lookup_blocks if self.lookup_blocks else 0.0

    @property
    def offline_hit_rate(self) -> float:
        """Fig.9's metric: prefix-cache hit ratio of offline prefills."""
        if not self.offline_lookup_blocks:
            return 0.0
        return self.offline_hit_blocks / self.offline_lookup_blocks


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, *,
                 task_aware: bool = True,
                 rc_provider: Optional[Callable[[int], int]] = None):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.task_aware = task_aware
        self.rc_provider = rc_provider or (lambda h: 0)
        self.blocks: List[Block] = [Block(i) for i in range(num_blocks)]
        self.free: List[int] = list(range(num_blocks))   # never-used / cleared
        self.hash_to_bid: Dict[int, int] = {}
        self._heap: List[Tuple[float, float, int, int]] = []  # lazy entries
        self._seq = itertools.count()
        self.threshold_blocks = num_blocks               # running-KV cap
        self.metrics = BlockManagerMetrics()

    # ------------------------------------------------------------- stats
    @property
    def running_blocks(self) -> int:
        return sum(1 for b in self.blocks if b.ref > 0)

    @property
    def cached_blocks(self) -> int:
        return sum(1 for b in self.blocks if b.ref == 0 and b.hash is not None)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def usage_breakdown(self) -> Dict[str, int]:
        """For the Fig.10 memory-occupancy benchmark."""
        out = {"running_online": 0, "running_offline": 0,
               "free_online": 0, "free_offline": 0, "unused": len(self.free)}
        for b in self.blocks:
            if b.ref > 0:
                key = "running_online" if b.task_type == TaskType.ONLINE else "running_offline"
                out[key] += 1
            elif b.hash is not None:
                key = "free_online" if b.task_type == TaskType.ONLINE else "free_offline"
                out[key] += 1
        return out

    # ------------------------------------------------------------- priority
    def _priority(self, blk: Block) -> float:
        if not self.task_aware:
            return 0.0                                    # pure LRU
        rc = self.rc_provider(blk.hash) + blk.unfinished_owners if blk.hash is not None else 0
        if blk.task_type == TaskType.ONLINE:
            if blk.unfinished_owners:
                return ONLINE_PREEMPTED_PRIORITY
            return ONLINE_FINISHED_PRIORITY
        return float(rc)

    def _push_evictable(self, blk: Block) -> None:
        heapq.heappush(self._heap, (self._priority(blk), blk.lat,
                                    next(self._seq), blk.bid))

    # ------------------------------------------------------------- probing
    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Longest cached full-block prefix (in tokens). Read-only."""
        n, prev, cached = 0, 0, 0
        bs = self.block_size
        while n + bs <= len(tokens):
            h = chain_hash(prev, tuple(tokens[n: n + bs]))
            if h not in self.hash_to_bid:
                break
            prev = h
            n += bs
            cached += bs
        return cached

    def evictable_count(self) -> int:
        return sum(1 for b in self.blocks if b.ref == 0 and b.hash is not None)

    def clean_evictable_count(self) -> int:
        """Evictable blocks whose eviction carries no punishment (priority
        < 1: dead offline, finished online) — plus never-used free blocks."""
        n = len(self.free)
        for b in self.blocks:
            if b.ref == 0 and b.hash is not None and self._priority(b) < 1.0:
                n += 1
        return n

    def can_allocate(self, n_new: int, *, respect_threshold: bool = True) -> bool:
        if len(self.free) + self.evictable_count() < n_new:
            return False
        if respect_threshold and self.task_aware:
            if self.running_blocks + n_new > self.threshold_blocks:
                return False
        return True

    # ------------------------------------------------------------- eviction
    def _evict_one(self) -> Optional[int]:
        while self._heap:
            prio, lat, _, bid = heapq.heappop(self._heap)
            blk = self.blocks[bid]
            if blk.ref > 0 or blk.hash is None:
                continue                                  # stale entry
            cur = (self._priority(blk), blk.lat)
            if (prio, lat) != cur:                        # stale meta: refresh
                self._push_evictable(blk)
                continue
            # evict
            rc = self.rc_provider(blk.hash) + blk.unfinished_owners
            if rc > 0:
                self.metrics.punished_tokens += blk.n_tokens
            del self.hash_to_bid[blk.hash]
            blk.hash = None
            blk.unfinished_owners = 0
            blk.n_tokens = 0
            self.metrics.evictions += 1
            return bid
        return None

    def _get_free_block(self) -> Optional[int]:
        if self.free:
            return self.free.pop()
        return self._evict_one()

    # ------------------------------------------------------------- alloc
    def allocate(self, req: Request, target_len: int, tokens: Sequence[int],
                 now: float, *, respect_threshold: bool = True) -> Optional[int]:
        """Ensure ``req`` owns blocks covering ``target_len`` token slots.

        ``tokens`` is the known token content (prompt + generated so far);
        full blocks within it are prefix-matched against the cache.
        Returns the number of *leading consecutive cache-hit tokens* among
        the newly covered blocks (0 if none), or None if memory is
        insufficient (partial-progress refs rolled back).
        """
        bs = self.block_size
        have = len(req.block_ids)
        need_blocks = (target_len + bs - 1) // bs
        if need_blocks <= have:
            return 0
        newly = []
        leading_hits = 0
        leading = True
        prev = self._chain_up_to(req, have, tokens)
        ok = True
        matching = True                  # only a *leading* prefix may hit
        for bi in range(have, need_blocks):
            start = bi * bs
            full = start + bs <= len(tokens)
            h = (chain_hash(prev, tuple(tokens[start: start + bs]))
                 if (full and matching) else None)
            offline = req.task_type == TaskType.OFFLINE
            if full:
                self.metrics.lookup_blocks += 1
                if offline:
                    self.metrics.offline_lookup_blocks += 1
            if h is not None and h in self.hash_to_bid:
                bid = self.hash_to_bid[h]
                blk = self.blocks[bid]
                blk.ref += 1
                blk.lat = now
                if blk.unfinished_owners > 0:
                    blk.unfinished_owners -= 1            # owner came back
                self.metrics.hit_blocks += 1
                if offline:
                    self.metrics.offline_hit_blocks += 1
                prev = h
                if leading:
                    leading_hits += bs
            else:
                matching = False
                leading = False
                if respect_threshold and self.task_aware and \
                        self.running_blocks + 1 > self.threshold_blocks:
                    ok = False
                bid = self._get_free_block() if ok else None
                if bid is None:
                    ok = False
                    break
                blk = self.blocks[bid]
                blk.ref = 1
                blk.lat = now
                blk.task_type = req.task_type
                blk.hash = None
                blk.n_tokens = 0
            newly.append(bid)
            req.block_ids.append(bid)
        if not ok:
            for bid in newly:
                self._release_block(bid, now)
                req.block_ids.pop()
            return None
        return leading_hits

    def _chain_up_to(self, req: Request, n_blocks: int, tokens: Sequence[int]) -> int:
        prev = 0
        bs = self.block_size
        for bi in range(n_blocks):
            if (bi + 1) * bs <= len(tokens):
                prev = chain_hash(prev, tuple(tokens[bi * bs: (bi + 1) * bs]))
        return prev

    def commit(self, req: Request, tokens: Sequence[int], now: float) -> None:
        """Register hashes for req's now-full computed blocks (content known)."""
        bs = self.block_size
        prev = 0
        covered = min(len(tokens), req.total_len)
        n_full = covered // bs
        # track valid tokens in the trailing partial block (for punishment)
        if n_full < len(req.block_ids) and covered % bs:
            self.blocks[req.block_ids[n_full]].n_tokens = covered % bs
        for bi in range(n_full):
            chunk = tuple(tokens[bi * bs: (bi + 1) * bs])
            h = chain_hash(prev, chunk)
            prev = h
            if bi >= len(req.block_ids):
                break
            blk = self.blocks[req.block_ids[bi]]
            blk.lat = now
            blk.n_tokens = bs
            if blk.hash is None and h not in self.hash_to_bid:
                blk.hash = h
                blk.task_type = req.task_type if blk.ref <= 1 else blk.task_type
                self.hash_to_bid[h] = blk.bid

    # ------------------------------------------------------------- free
    def _release_block(self, bid: int, now: float, unfinished: bool = False) -> None:
        blk = self.blocks[bid]
        blk.ref -= 1
        blk.lat = now
        if blk.ref == 0:
            if unfinished:
                blk.unfinished_owners += 1
            if blk.hash is None:
                if unfinished:                            # lost work: re-prefill
                    self.metrics.punished_tokens += blk.n_tokens
                blk.n_tokens = 0
                blk.unfinished_owners = 0
                self.free.append(bid)                     # uncommitted: discard
            else:
                self._push_evictable(blk)

    def free_request(self, req: Request, now: float, *, finished: bool) -> None:
        for bid in req.block_ids:
            self._release_block(bid, now, unfinished=not finished)
        req.block_ids.clear()

    def trim_request(self, req: Request, keep_tokens: int, now: float) -> None:
        """Release blocks beyond the ``keep_tokens`` boundary — allocated for
        a planned chunk that was then shed before computing anything, so no
        work is lost: fresh blocks return to the free list, cache-hit blocks
        just drop the extra reference and stay cached."""
        keep = (keep_tokens + self.block_size - 1) // self.block_size
        while len(req.block_ids) > keep:
            self._release_block(req.block_ids.pop(), now)

    def touch(self, req: Request, now: float) -> None:
        for bid in req.block_ids:
            self.blocks[bid].lat = now
