"""§5.4 resource & throughput simulation for system deployers.

Step 1: enumerate resources (KV blocks ≈ GPU memory) smallest→largest over
a short peak-workload window until online SLOs are met.
Step 2: with chosen resources, simulate an extended period to estimate the
maximum offline throughput.

Both replay the *actual* scheduler + KV manager (EchoEngine with
model=None), clocked by the calibrated time model — exactly the paper's
methodology.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.engine import EchoEngine, EngineStats
from repro.core.estimator import TimeModel
from repro.core.policies import ECHO, PolicyConfig
from repro.core.request import Request


def clone_requests(reqs: Sequence[Request],
                   preserve_rid: bool = False) -> List[Request]:
    """Fresh, unstarted copies — requests mutate as they run, so every
    simulation must get its own. ``preserve_rid=True`` keeps the template
    rids, making two simulations of the same workload bit-identical (the
    simulator fabricates tokens per-rid); only safe when each clone set runs
    in its own engine/cluster, since rids must stay unique within one."""
    out = []
    for r in reqs:
        kw = {"rid": r.rid} if preserve_rid else {}
        out.append(Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                           task_type=r.task_type, arrival_time=r.arrival_time,
                           slo=r.slo, **kw))
    return out


def simulate(online: Sequence[Request], offline: Sequence[Request],
             time_model: TimeModel, num_blocks: int, *,
             policy: PolicyConfig = ECHO, block_size: int = 16,
             chunk_size: int = 64, clock_model=None,
             duration: Optional[float] = None,
             max_iters: int = 20_000) -> EngineStats:
    """``clock_model`` (optional) is the ground-truth clock when it differs
    from the scheduler's ``time_model`` estimate — §5 calibration studies."""
    eng = EchoEngine(None, None, policy, num_blocks=num_blocks,
                     block_size=block_size, chunk_size=chunk_size,
                     time_model=time_model, clock_model=clock_model)
    for r in clone_requests(online) + clone_requests(offline):
        eng.submit(r)
    return eng.run(max_iters=max_iters, until_time=duration)


@dataclass
class CapacityReport:
    min_blocks_for_slo: Optional[int]
    slo_by_blocks: List[Tuple[int, float]]
    offline_throughput: Optional[float] = None


def estimate_capacity(online_peak: Sequence[Request],
                      offline: Sequence[Request],
                      time_model: TimeModel, *,
                      candidate_blocks: Sequence[int] = (64, 128, 256, 512, 1024),
                      slo_target: float = 0.9,
                      policy: PolicyConfig = ECHO,
                      block_size: int = 16,
                      duration: Optional[float] = None) -> CapacityReport:
    """Step 1 (+ Step 2 at the chosen size)."""
    tried = []
    chosen = None
    for nb in sorted(candidate_blocks):
        stats = simulate(online_peak, [], time_model, nb, policy=policy,
                         block_size=block_size, duration=duration)
        att = min(stats.slo_attainment("ttft"), stats.slo_attainment("tpot"))
        tried.append((nb, att))
        if att >= slo_target and chosen is None:
            chosen = nb
            break
    report = CapacityReport(min_blocks_for_slo=chosen, slo_by_blocks=tried)
    if chosen is not None:
        stats = simulate(online_peak, offline, time_model, chosen,
                         policy=policy, block_size=block_size,
                         duration=duration)
        report.offline_throughput = stats.offline_throughput()
    return report
