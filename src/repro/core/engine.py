"""Echo serving engine: executes scheduler plans on a real JAX model.

Continuous-batching loop (vLLM-style): each iteration the scheduler emits a
plan (prefill chunks + decode batch + preemptions); the engine executes it
on the paged runner, advances the clock, feeds the estimators, and records
metrics. The clock is either a ground-truth ``clock_model`` ("virtual" —
used by the SLO benchmarks; deterministic, exactly the paper's simulator
methodology) or wall time ("wall"). The scheduler's ``time_model`` is only
an *estimate* of that clock: pass a different (or perturbed) ``clock_model``
to study miscalibration, and an ``OnlineCalibrator`` (``policy.calibrate``)
to refit the estimate from the observed iteration times (§5).

Host-tier KV staging overlaps with compute (``TimeModel.swap_overlap``):
the virtual clock charges ``max(compute, transfer) + launch`` and on the
wall path a single-worker copy stream (``_SwapStager``) double-buffers
payload staging against the runner, with per-block completion fences
before any page a plan reads or writes.
"""
from __future__ import annotations

import bisect
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.block_io import BlockIOSpec, io_spec_for_model, paged_spec
from repro.core.block_manager import BlockManager, HostBlock, prefix_chain
from repro.core.calibration import OnlineCalibrator
from repro.core.estimator import MemoryPredictor, TimeModel
from repro.core.policies import PolicyConfig
from repro.core.radix_pool import OfflinePool
from repro.core.request import Request, RequestState
from repro.core.scheduler import Scheduler
from repro.models.model import Model
from repro.models.paged import PagedRunner

MAX_STALLS = 3      # consecutive no-progress iterations before giving up


@dataclass
class IterationRecord:
    t: float
    n_prefill: int
    n_decode: int
    n_online: int
    n_offline: int
    iter_time: float
    offline_tokens: int
    online_tokens: int
    usage: Dict[str, int] = field(default_factory=dict)
    hit_rate: float = 0.0
    threshold_blocks: int = 0
    swap_in_tokens: int = 0        # tokens restored from the host tier
    swap_out_tokens: int = 0       # tokens parked on the host tier
    swap_in_bytes: int = 0         # PCIe bytes of the restores (lazy = 0)
    swap_out_bytes: int = 0        # PCIe bytes of the parks
    host_blocks: int = 0           # host-tier occupancy at iteration end
    swap_transfer_time: float = 0.0  # PCIe seconds put on the copy stream
    swap_exposed_time: float = 0.0   # the tail NOT hidden under compute
    migrate_in_bytes: int = 0      # fabric bytes received from other replicas


@dataclass
class IterationDetail:
    """What the observability layer needs beyond ``IterationRecord``: the
    plan's shape and the estimate it was scored with. Built only when a
    listener overrides ``on_iteration`` — the plain serving path never
    pays for it."""
    t_start: float
    t_end: float
    schedule_wall: float           # wall seconds spent in scheduler.schedule
    compute_time: float            # the clock's compute leg (no transfers)
    predicted_time: float          # scheduler estimate of the iteration
    admitted: List[Request]        # newly admitted to the running batch
    prefill_spans: List[Tuple[Request, int, int]]   # (req, start, end)
    decodes: List[Request]


class EngineListener:
    """Engine-level lifecycle hooks, called synchronously from ``step()``.

    The serving layer (``repro.serving``) subscribes one of these per engine
    to stream token/preempt/finish events live instead of scraping
    ``EngineStats`` after the fact. All methods are no-ops by default so a
    listener overrides only what it needs. Callbacks run at iteration end
    (after the plan executed), so aborting requests from inside one is safe.
    """

    def on_token(self, req: Request, tok: int, t: float) -> None: ...

    def on_preempt(self, req: Request, t: float) -> None: ...

    def on_finish(self, req: Request, t: float) -> None: ...

    def on_swap_in(self, req: Request, n_tokens: int, t: float) -> None: ...

    def on_swap_out(self, n_tokens: int, t: float) -> None: ...

    def on_swap_overlap(self, transfer_s: float, exposed_s: float,
                        t: float) -> None: ...

    def on_iteration(self, rec: "IterationRecord",
                     detail: "IterationDetail") -> None:
        """Per-iteration observability hook (tracing + estimator-drift
        probes). The engine only builds ``detail`` when some attached
        listener overrides this method."""
        ...


class _SwapStager:
    """One async copy "stream" for host<->device KV staging (wall path).

    Split-phase contract with the runner:
      * swap-out — the device-side page slice is dispatched on the engine
        thread at launch (dispatch order sequences it before any later
        compute overwrites the page); the blocking D2H materialization runs
        on the worker.
      * swap-in — the worker uploads the payload H2D off-thread; the cheap
        donated scatter into the page pool stays with the engine thread and
        applies at fence time (the pool is single-owner state).

    ``fence(bids)`` MUST run before the runner reads or writes any of
    ``bids``. Entries stay tracked until fenced — a swapped-in block whose
    owner was preempted and whose page is only touched many iterations
    later still gets its payload applied before first use. ``launch``
    fences a bid that is being re-purposed while a previous transfer is
    still in flight, preserving journal order per page."""

    def __init__(self, runner):
        self.runner = runner
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="kv-stage")
        self._inflight: Dict[int, Tuple[str, Future]] = {}
        self.staged_wall = 0.0      # seconds of staging done on the worker
        self.exposed_wall = 0.0     # seconds the engine blocked in fences
        # (bytes, worker seconds) per transfer, for swap-term calibration;
        # bounded so a virtual-clock run that never drains cannot grow it.
        # The lock serializes worker appends against the engine's drain.
        self._samples: List[Tuple[int, float]] = []
        self._samples_lock = threading.Lock()

    def launch(self, events) -> None:
        for kind, bid, hb in events:
            if bid in self._inflight:
                self.fence([bid])
            if kind == "out":
                snap = self.runner.snapshot_block(bid)
                fut = self._pool.submit(self._stage_out, hb, snap)
            elif kind == "in_lazy":
                # restore_last_only families: the payload re-registers
                # host-side without an upload — but it must still ride the
                # worker FIFO so an "out" of the same content earlier this
                # iteration has produced the payload before we hand it over
                fut = self._pool.submit(self._stage_lazy, hb)
            else:
                fut = self._pool.submit(self._stage_in, hb)
            self._inflight[bid] = (kind, fut)

    def _stage_out(self, hb, snap):
        t0 = time.perf_counter()
        hb.payload = self.runner.materialize(snap)
        self._account(hb.n_bytes, time.perf_counter() - t0)
        return None

    def _stage_in(self, hb):
        # single-worker FIFO: the "out" that produced this payload (possibly
        # this very iteration) has already run by the time we get here
        assert hb.payload is not None, \
            f"swap-in of block hash {hb.hash} with no staged payload"
        t0 = time.perf_counter()
        staged = self.runner.stage_payload(hb.payload)
        self._account(hb.n_bytes, time.perf_counter() - t0)
        return staged

    def _stage_lazy(self, hb):
        # no link traffic and no calibration sample: a lazy restore only
        # hands the (already host-resident) payload back to the runner
        assert hb.payload is not None, \
            f"lazy swap-in of block hash {hb.hash} with no staged payload"
        return hb.payload

    def _account(self, n_bytes: int, dt: float) -> None:
        with self._samples_lock:
            self.staged_wall += dt
            if len(self._samples) < 2048:
                self._samples.append((n_bytes, dt))

    def fence(self, bids: Iterable[int]) -> None:
        """Complete every in-flight transfer touching ``bids``: block on
        the worker and, for swap-ins, apply the pool scatter."""
        for bid in list(bids):
            entry = self._inflight.pop(bid, None)
            if entry is None:
                continue
            kind, fut = entry
            t0 = time.perf_counter()
            staged = fut.result()
            if kind == "in":
                self.runner.write_block(bid, staged)
            elif kind == "in_lazy":
                self.runner.write_block_lazy(bid, staged)
            self.exposed_wall += time.perf_counter() - t0

    def flush(self) -> None:
        self.fence(list(self._inflight))

    def inflight_blocks(self) -> int:
        return len(self._inflight)

    def drain_samples(self) -> List[Tuple[int, float]]:
        with self._samples_lock:
            out, self._samples = self._samples, []
        return out


@dataclass
class EngineStats:
    iterations: List[IterationRecord] = field(default_factory=list)
    finished: List[Request] = field(default_factory=list)
    aborted: List[Request] = field(default_factory=list)

    def offline_throughput(self) -> float:
        """Completed offline work (prompt + generated tokens of finished
        offline requests) per second. Reused prefixes count as progress —
        that is precisely the benefit of prefix caching."""
        if not self.iterations:
            return 0.0
        done = [r for r in self.finished if not r.is_online]
        total = sum(r.prompt_len + r.n_output for r in done)
        # makespan of the offline work: last instant offline was active
        t = max((r.t for r in self.iterations if r.offline_tokens > 0),
                default=self.iterations[-1].t)
        return total / (t + 1e-9)

    def offline_computed_rate(self) -> float:
        """Offline tokens actually computed / s (excludes cache-skipped)."""
        if not self.iterations:
            return 0.0
        total = sum(r.offline_tokens for r in self.iterations)
        return total / (self.iterations[-1].t + 1e-9)

    @property
    def swapped_in_tokens(self) -> int:
        """Total tokens restored host->device instead of recomputed."""
        return sum(r.swap_in_tokens for r in self.iterations)

    @property
    def swapped_out_tokens(self) -> int:
        """Total tokens parked device->host instead of dropped."""
        return sum(r.swap_out_tokens for r in self.iterations)

    @property
    def swapped_in_bytes(self) -> int:
        """Total PCIe bytes of restores (what the link actually moved)."""
        return sum(r.swap_in_bytes for r in self.iterations)

    @property
    def swapped_out_bytes(self) -> int:
        """Total PCIe bytes of parks."""
        return sum(r.swap_out_bytes for r in self.iterations)

    @property
    def migrated_in_bytes(self) -> int:
        """Total fabric bytes of cross-replica prefix arrivals clocked."""
        return sum(r.migrate_in_bytes for r in self.iterations)

    @property
    def swap_transfer_time(self) -> float:
        """Total PCIe seconds put on the copy stream."""
        return sum(r.swap_transfer_time for r in self.iterations)

    @property
    def swap_exposed_time(self) -> float:
        """Transfer seconds NOT hidden under compute (what the clock and
        the SLO budget actually paid)."""
        return sum(r.swap_exposed_time for r in self.iterations)

    def swap_hidden_frac(self) -> float:
        """Fraction of swap traffic the overlap hid: 0.0 on the serial
        path, approaching 1.0 when compute fully covers the transfers."""
        transfer = self.swap_transfer_time
        if transfer <= 0.0:
            return 0.0
        return max(1.0 - self.swap_exposed_time / transfer, 0.0)

    def slo_attainment(self, kind: str = "ttft") -> float:
        """Fraction of decidable online requests meeting the SLO. Requests
        for which the metric is undefined (no first token for ttft; fewer
        than 2 output tokens for tpot) are excluded from the denominator —
        counting them as hits or misses would skew the two kinds opposite
        ways."""
        online = [r for r in self.finished if r.is_online and r.slo]
        ok = n = 0
        for r in online:
            v = r.ttft() if kind == "ttft" else r.tpot()
            if v is None:
                continue
            n += 1
            ok += v <= (r.slo.ttft if kind == "ttft" else r.slo.tpot)
        return ok / n if n else 1.0


class EchoEngine:
    """With model+params this executes real forwards on the paged runner;
    with ``model=None`` it is the paper's §5.4 simulator: the same scheduler
    + KV manager loop, clocked purely by the time model (tokens fabricated
    per-request deterministically so block hashing stays realistic)."""

    def __init__(self, model: Optional[Model], params, policy: PolicyConfig, *,
                 num_blocks: int = 256, block_size: int = 16,
                 chunk_size: int = 64, max_pages_per_seq: int = 32,
                 time_model: Optional[TimeModel] = None,
                 clock_model=None, calibrator: Optional[OnlineCalibrator] = None,
                 clock: str = "virtual", seed: int = 0,
                 max_batch_tokens: int = 2048, max_running: int = 64,
                 host_kv_blocks: int = 0,
                 io_spec: Optional[BlockIOSpec] = None,
                 attn_impl: str = "auto",
                 kernel_profile: Optional[str] = None):
        self.model = model
        self.policy = policy
        self.clock = clock
        self.pool = OfflinePool(block_size)
        # byte pricing of this engine's blocks: derived from the model's
        # architecture (paged KV pages vs. fixed-size state snapshots), the
        # 8B-magnitude paged default on the model-less simulator path
        if io_spec is None:
            io_spec = (io_spec_for_model(model) if model is not None
                       else paged_spec())
        self.io = io_spec
        self.bm = BlockManager(num_blocks, block_size,
                               task_aware=policy.task_aware_kv,
                               rc_provider=self.pool.rc,
                               host_blocks=host_kv_blocks,
                               io=io_spec)
        self.tm = time_model or TimeModel()
        # Ground-truth clock vs. scheduler estimate (§5 calibration loop):
        # `tm` is what the scheduler *believes*; `clock_model` is what the
        # hardware *does* (a different preset or a PerturbedTimeModel).
        # Defaulting to `tm` keeps the classic perfect-estimate simulator.
        self.clock_model = clock_model if clock_model is not None else self.tm
        self.calibrator = calibrator
        if self.calibrator is None and policy.calibrate:
            self.calibrator = OnlineCalibrator(self.tm)
        self.scheduler = Scheduler(self.bm, self.pool, self.tm, policy,
                                   chunk_size=chunk_size,
                                   max_batch_tokens=max_batch_tokens,
                                   max_running=max_running)
        self.runner = None
        if model is not None:
            if set(model.cfg.attn_layers) <= {"attn", "moe"}:
                self.runner = PagedRunner(model, params, num_blocks,
                                          block_size, max_pages_per_seq,
                                          chunk_size, attn_impl=attn_impl,
                                          kernel_profile=kernel_profile)
            else:
                from repro.models.state_cache import StateRunner
                self.runner = StateRunner(model, params, num_blocks,
                                          block_size, max_pages_per_seq,
                                          chunk_size)
        # async swap/compute overlap (wall path): a single-worker copy
        # stream double-buffers payload staging against runner compute, with
        # per-block fences before first touch. Gated on the same switch the
        # virtual clock and the scheduler's estimate use (tm.swap_overlap).
        self._stager: Optional[_SwapStager] = None
        if (self.runner is not None and self.bm.host is not None
                and hasattr(self.runner, "snapshot_block")
                and getattr(self.tm, "swap_overlap", False)):
            self._stager = _SwapStager(self.runner)
        # cumulative stager seconds already attributed to an iteration
        # record — worker staging that lands between steps (or during idle
        # launches) is picked up by the NEXT record instead of dropped
        self._staged_seen = 0.0
        self._exposed_seen = 0.0
        self.mem_pred = MemoryPredictor(window=120.0)
        self.now = 0.0
        self.stats = EngineStats()
        self._pending_swap_out = 0     # staged on an idle tick; next record
        self._pending_swap_out_bytes = 0
        self._pending_swap_in_bytes = 0
        self._pending_swap_wall = 0.0  # its wall time (wall-clock path)
        self._pending_migrate_in_bytes = 0  # fabric arrivals awaiting clock
        self.pending: List[Request] = []       # (arrival_time, rid) ordered
        self.listeners: List[EngineListener] = []
        self._rng = np.random.default_rng(seed)
        # step() is not reentrant and not thread-safe: the real-time layer
        # drives it from a worker thread (asyncio.to_thread), so a second
        # concurrent driver must fail loudly instead of corrupting the
        # scheduler/KV state mid-iteration
        self._step_lock = threading.Lock()

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        bisect.insort(self.pending, req,
                      key=lambda r: (r.arrival_time, r.rid))

    def _pull_arrivals(self) -> None:
        prev = -float("inf")
        while self.pending and self.pending[0].arrival_time <= self.now:
            req = self.pending.pop(0)
            assert req.arrival_time >= prev, "pending drained out of order"
            prev = req.arrival_time
            self.scheduler.submit(req)

    def abort(self, req: Request) -> bool:
        """Cancel a request mid-flight: remove it from every intake and
        scheduler structure it sits in and release all its resources — KV
        blocks (``finished=True``: an aborted owner never returns, so no
        unfinished-owner pins), radix-pool membership (dropping its RC
        contribution), and any live runner state. Returns False for
        already-terminal requests, True otherwise. Safe to call between
        iterations or from an ``EngineListener`` callback."""
        if req.state in (RequestState.FINISHED, RequestState.ABORTED):
            return False
        found = False
        if req in self.pending:
            self.pending.remove(req)
            found = True
        sched = self.scheduler
        if req in sched.online_queue:
            sched.online_queue.remove(req)
            found = True
        if req in self.pool:
            self.pool.remove(req)
            found = True
        if req in sched.running:
            sched.running.remove(req)
            found = True
        if req.block_ids:
            self.bm.free_request(req, self.now, finished=True)
            found = True
        if not found:
            return False            # not this engine's request
        # a previously-preempted request holds unfinished-owner pins on
        # committed blocks it no longer references (device or host tier) —
        # the aborted owner never returns, so the pins must drop too
        self.bm.release_owner_pins(req)
        if self.runner is not None:
            self.runner.release(req.rid)
        req.state = RequestState.ABORTED
        self.stats.aborted.append(req)
        return True

    # ------------------------------------------------------------- helpers
    def _fabricate(self, req: Request) -> np.ndarray:
        """Simulator mode: deterministic pseudo-random next-token logits
        (per request) so generated-block hashes stay realistic."""
        rng = np.random.default_rng((req.rid << 20) + req.n_output)
        out = np.zeros(128, np.float32)
        out[rng.integers(0, 128)] = 1.0
        return out

    def _emit(self, req: Request, logits: np.ndarray) -> None:
        if req.state == RequestState.ABORTED:
            return          # aborted from a listener callback this iteration
        tok = int(np.argmax(logits))
        req.record_token(tok, self.now)
        for l in self.listeners:
            l.on_token(req, tok, self.now)
        if req.done:
            self.bm.free_request(req, self.now, finished=True)
            # discharge stale owner pins: a request that was preempted and
            # then recomputed (rather than swapped back) may still pin the
            # host copies of blocks it re-registered on device
            self.bm.release_owner_pins(req)
            if req in self.scheduler.running:
                self.scheduler.running.remove(req)
            if self.runner is not None:
                self.runner.release(req.rid)
            self.stats.finished.append(req)
            for l in self.listeners:
                l.on_finish(req, self.now)

    def predicted_first_token_latency(self, req: Request) -> float:
        """Engine-local time to ``req``'s first token if placed here: its own
        prefill plus all online prefill work ahead of it, overlapped with the
        running decode batch (Eq.6-8), plus any clock skew (an engine whose
        virtual clock is already past the arrival cannot start it earlier
        than its own ``now``). Uses the scheduler's — possibly
        online-calibrated — estimate model. Shared by the cluster router's
        online placement and the serving layer's SLO-feasibility shedding."""
        sched = self.scheduler
        spans = [(0, len(req.prompt))]
        for r in sched.online_queue:
            spans.append((0, len(r.full_tokens)))
        for r in self.pending:
            if r.is_online:
                spans.append((0, len(r.full_tokens)))
        for r in sched.running:
            if r.is_online and not r.prefill_done:
                spans.append((r.computed_tokens, r.prefill_target_len))
        dlens = [r.total_len + 1 for r in sched.running
                 if r.prefill_done and not r.done]
        t = self.tm.batch_time(spans, dlens)
        return t + max(self.now - req.arrival_time, 0.0)

    def _online_kv_tokens(self) -> int:
        return sum(r.total_len for r in self.scheduler.running if r.is_online)

    # --------------------------------------------------------- load signals
    # Single source of truth for the accounting shared by cluster replicas
    # (router placement) and serving backends (admission control).
    def has_work(self) -> bool:
        return bool(self.pending or self.scheduler.online_queue
                    or self.scheduler.running or len(self.pool))

    def online_queue_depth(self) -> int:
        """Online requests waiting to run: queued at the scheduler or still
        in the pending intake."""
        n = len(self.scheduler.online_queue)
        n += sum(1 for r in self.pending if r.is_online)
        return n

    def offline_backlog(self) -> int:
        """Pooled + pending + running offline work."""
        n = len(self.pool)
        n += sum(1 for r in self.pending if not r.is_online)
        n += sum(1 for r in self.scheduler.running if not r.is_online)
        return n

    def _execute_swaps(self) -> Tuple[int, int, int]:
        """Dispatch the block staging of this iteration's swap decisions.

        With the async stager (wall path, overlap on) this only *launches*
        the transfers: device-side snapshots are dispatched here — before
        any runner write, while an "out" block's payload is still intact —
        and the blocking copies run on the copy worker; the per-request
        fences in ``step`` complete whatever the plan actually touches.
        Without it (overlap off, or no backing runner) payloads are staged
        inline exactly as before. On the virtual path the journal is
        drained for accounting alone. Returns (swapped-out tokens,
        swapped-out bytes, swapped-in bytes) — swap-in *tokens* are known
        from the plan, but the link-clocked byte weights come from the
        journal, where "in_lazy" restores correctly weigh zero."""
        events = self.bm.drain_swap_events()
        out_tokens = sum(hb.n_tokens for kind, _, hb in events
                         if kind == "out")
        out_bytes = sum(hb.n_bytes for kind, _, hb in events
                        if kind == "out")
        in_bytes = sum(hb.n_bytes for kind, _, hb in events
                       if kind == "in")
        if self._stager is not None:
            self._stager.launch(events)
            return out_tokens, out_bytes, in_bytes
        stage = self.runner is not None and hasattr(self.runner, "read_block")
        for kind, bid, hb in events:
            if kind == "out":
                if stage:
                    hb.payload = self.runner.read_block(bid)
            elif stage:
                assert hb.payload is not None, \
                    f"swap-in of block hash {hb.hash} with no staged payload"
                if kind == "in_lazy":
                    self.runner.write_block_lazy(bid, hb.payload)
                else:
                    self.runner.write_block(bid, hb.payload)
        return out_tokens, out_bytes, in_bytes

    def _fence(self, bids: Iterable[int]) -> None:
        """Complete in-flight staging on the blocks a runner call is about
        to touch (no-op without the async stager)."""
        if self._stager is not None:
            self._stager.fence(bids)

    def _observe_swap_clock(self, swap_in_bytes: int, swap_out_bytes: int,
                            compute_time: float, iter_time: float,
                            swap_transfer: float) -> None:
        """Feed the calibrator's swap-term windows (ROADMAP: swap terms were
        static after ``fit_swap``): per-event copy-worker timings on the
        wall path, the ground-truth clock's transfer legs on the virtual
        path, and — when overlap is active — the (compute, bytes, total)
        triple that refits the launch overhead. Byte-denominated: KV pages
        and state snapshots feed one pool that recovers the link rate."""
        cal = self.calibrator
        total_bytes = swap_in_bytes + swap_out_bytes
        if self._stager is not None and self.clock != "virtual":
            for n, dt in self._stager.drain_samples():
                cal.observe_swap(n, dt)
        elif self.clock == "virtual":
            if not hasattr(self.clock_model, "swap_time"):
                return
            if swap_in_bytes:
                cal.observe_swap(swap_in_bytes,
                                 self.clock_model.swap_time(swap_in_bytes))
            if swap_out_bytes:
                cal.observe_swap(swap_out_bytes,
                                 self.clock_model.swap_time(swap_out_bytes))
        elif total_bytes and swap_transfer > 0.0:
            cal.observe_swap(total_bytes, swap_transfer)
        if total_bytes and getattr(self.tm, "swap_overlap", False):
            cal.observe_overlap(compute_time, total_bytes, iter_time)

    # ---------------------------------------------------------- migration
    def export_prefix(self, tokens) -> Tuple[List[HostBlock], int]:
        """Pull the leading cached prefix of ``tokens`` out of this engine
        as shippable ``HostBlock``s — the source side of cross-replica KV
        migration (a draining replica, or one the router just stole from).
        In-flight staging is flushed first so every payload is settled; the
        walk stops at the first block absent from both tiers or still
        referenced by a running request. Returns (blocks, total fabric
        bytes). The *destination* engine charges the fabric time."""
        self.flush_swaps()
        reader = None
        if self.runner is not None and hasattr(self.runner, "read_block"):
            reader = self.runner.read_block
        out: List[HostBlock] = []
        for h in prefix_chain(tokens, self.bm.block_size):
            hb = self.bm.export_block(h, reader)
            if hb is None:
                break
            out.append(hb)
        return out, sum(hb.n_bytes for hb in out)

    def import_prefix(self, hbs: Iterable[HostBlock]) -> int:
        """Land migrated blocks in this engine's host tier, where the
        ordinary swap-in path restores them exactly like a locally parked
        prefix. Admitted bytes are charged to the next iteration's transfer
        leg at the ground-truth clock's ``migrate_time`` rate. Returns the
        bytes actually admitted (duplicates and host-tier bounces are
        free — nothing crossed the fabric)."""
        n_bytes = 0
        for hb in hbs:
            if self.bm.import_host_block(hb, self.now):
                n_bytes += hb.n_bytes
        self._pending_migrate_in_bytes += n_bytes
        return n_bytes

    def next_arrival_time(self) -> Optional[float]:
        """Earliest pending arrival (engine-clock domain), or None. The
        real-time loop uses it to sleep precisely while idle instead of
        spinning on ``step``."""
        return self.pending[0].arrival_time if self.pending else None

    def flush_swaps(self) -> None:
        """Land every in-flight host<->device staging transfer. ``run``
        calls this before going idle; the real-time layer calls it during
        graceful drain so no swap payload is lost when the loop stops."""
        if self._stager is not None:
            self._stager.flush()

    # ------------------------------------------------------------- step
    def step(self) -> Optional[IterationRecord]:
        """One scheduler+execute iteration. Serialized: a second driver
        entering while an iteration is mid-flight (the RT loop's worker
        thread vs. a direct caller) raises instead of interleaving."""
        if not self._step_lock.acquire(blocking=False):
            raise RuntimeError(
                "EchoEngine.step() re-entered while an iteration is in "
                "flight — the engine must have exactly one driver")
        try:
            return self._step_impl()
        finally:
            self._step_lock.release()

    def _step_impl(self) -> Optional[IterationRecord]:
        self._pull_arrivals()
        tsched = time.perf_counter()
        plan = self.scheduler.schedule(self.now)
        ts0 = time.perf_counter()
        schedule_wall = ts0 - tsched
        out_tok, out_bytes, in_bytes = self._execute_swaps()
        swap_out_tokens = out_tok + self._pending_swap_out
        swap_out_bytes = out_bytes + self._pending_swap_out_bytes
        swap_in_bytes = in_bytes + self._pending_swap_in_bytes
        swap_wall = time.perf_counter() - ts0 + self._pending_swap_wall
        migrate_in_bytes = self._pending_migrate_in_bytes
        self._pending_swap_out = 0
        self._pending_swap_out_bytes = 0
        self._pending_swap_in_bytes = 0
        self._pending_swap_wall = 0.0
        self._pending_migrate_in_bytes = 0
        swap_in_tokens = plan.swap_in_tokens
        if plan.n_scheduled == 0 and not plan.swap_ins:
            # an empty plan can still carry preemptions (victims freed for
            # an admission that then failed): their runner state and
            # listener events must not be skipped
            if plan.preempted:
                if self.runner is not None:
                    for req in plan.preempted:
                        self.runner.release(req.rid)
                for req in plan.preempted:
                    for l in self.listeners:
                        l.on_preempt(req, self.now)
            self._pending_swap_out = swap_out_tokens
            self._pending_swap_out_bytes = swap_out_bytes
            self._pending_swap_in_bytes = swap_in_bytes
            self._pending_swap_wall += swap_wall
            self._pending_migrate_in_bytes = migrate_in_bytes
            # idle: advance to next arrival
            if self.pending:
                self.now = max(self.now, self.pending[0].arrival_time)
                return None
            return None

        st = self._stager
        exposed_pre = st.exposed_wall if st is not None else 0.0
        t0 = time.perf_counter()
        offline_tokens = 0
        online_tokens = 0
        emissions = []
        if self.runner is not None:
            for req in plan.preempted:      # drop live recurrent state
                self.runner.release(req.rid)

        # ---- prefill chunks (one by one, §5.2)
        for req, chunk in plan.prefills:
            start = req.computed_tokens
            toks = req.full_tokens[start: start + chunk]
            if self.runner is not None:
                # complete in-flight staging on this request's blocks only —
                # other requests' transfers keep overlapping with this chunk
                self._fence(req.block_ids)
                logits = self.runner.prefill_chunk(list(toks), start,
                                                   req.block_ids, rid=req.rid)
            else:
                logits = self._fabricate(req)
            req.computed_tokens = start + chunk
            self.bm.commit(req, req.full_tokens, self.now)
            if req.is_online:
                online_tokens += chunk
            else:
                offline_tokens += chunk
            if req.n_preemptions and start < req.prefill_target_len:
                req.recomputed_tokens += chunk
            if req.prefill_done:
                emissions.append((req, logits))

        # ---- decode batch
        decodes = [r for r in plan.decodes if not r.done]
        if decodes:
            if self.runner is not None:
                self._fence({b for r in decodes for b in r.block_ids})
                tokens = [r.full_tokens[r.computed_tokens] for r in decodes]
                bts = [r.block_ids for r in decodes]
                pos = [r.computed_tokens for r in decodes]
                logits = self.runner.decode(tokens, bts, pos,
                                            rids=[r.rid for r in decodes])
            else:
                logits = np.stack([self._fabricate(r) for r in decodes])
            for i, req in enumerate(decodes):
                req.computed_tokens += 1
                self.bm.commit(req, req.full_tokens, self.now)
                if req.is_online:
                    online_tokens += 1
                else:
                    offline_tokens += 1
                emissions.append((req, logits[i]))

        wall = time.perf_counter() - t0
        spans = [(r.computed_tokens - c, r.computed_tokens)
                 for r, c in plan.prefills]
        dlens = [r.total_len for r in decodes]
        # PCIe swap traffic — BOTH directions — is clocked separately from
        # compute: the calibrator must see pure compute time or the Eq.6-8
        # refit would absorb transfer cost into the prefill coefficients.
        # Under overlap only the *exposed* tail reaches the iteration time:
        # the virtual clock charges max(compute, transfer) + launch, and on
        # the wall path the copy worker really did stage concurrently — the
        # fence stalls inside the runner window are the exposed tail.
        clock = self.clock_model
        transfer = ((clock.swap_time(swap_in_bytes)
                     + clock.swap_time(swap_out_bytes))
                    if hasattr(clock, "swap_time") else 0.0)
        # cross-replica arrivals ride the same copy-stream leg, priced at
        # the inter-node fabric rate instead of the local PCIe rate
        migrate_transfer = (clock.migrate_time(migrate_in_bytes)
                            if migrate_in_bytes
                            and hasattr(clock, "migrate_time") else 0.0)
        transfer += migrate_transfer
        if self.clock == "virtual":
            compute_time = clock.batch_time(spans, dlens)
            if transfer > 0.0 and hasattr(clock, "overlapped_iteration_time"):
                iter_time = clock.overlapped_iteration_time(compute_time,
                                                            transfer)
            else:
                iter_time = compute_time + transfer
            swap_transfer = transfer
            swap_exposed = iter_time - compute_time
        elif st is not None:
            # attribute everything accrued since the last record (staging
            # from the scheduling gap / idle launches included), but only
            # subtract the fences that stalled THIS runner window from the
            # calibrator's compute sample
            swap_transfer = st.staged_wall - self._staged_seen
            swap_exposed = st.exposed_wall - self._exposed_seen
            self._staged_seen = st.staged_wall
            self._exposed_seen = st.exposed_wall
            compute_time = max(wall - (st.exposed_wall - exposed_pre), 0.0)
            iter_time = wall + swap_wall      # swap_wall: launch overhead
        else:
            # synchronous staging happened in _execute_swaps, outside the
            # runner window, so its measured time is added back — fully
            # exposed, exactly the pre-overlap wall clock
            swap_transfer = swap_exposed = swap_wall
            compute_time = wall
            iter_time = wall + swap_wall
        self.now += iter_time
        if self.calibrator is not None:
            # feed the observed clock back into the scheduler's estimate
            self.calibrator.observe(self.now, spans, dlens, compute_time)
            self._observe_swap_clock(swap_in_bytes, swap_out_bytes,
                                     compute_time, iter_time,
                                     swap_transfer - migrate_transfer)
            if migrate_transfer > 0.0:
                self.calibrator.observe_migration(migrate_in_bytes,
                                                  migrate_transfer)
        for req, lg in emissions:               # tokens arrive at iteration end
            self._emit(req, lg)
        for req in plan.preempted:
            for l in self.listeners:
                l.on_preempt(req, self.now)
        if swap_out_tokens:
            for l in self.listeners:
                l.on_swap_out(swap_out_tokens, self.now)
        for req, n in plan.swap_ins:
            for l in self.listeners:
                l.on_swap_in(req, n, self.now)
        if swap_transfer > 0.0:
            for l in self.listeners:
                l.on_swap_overlap(swap_transfer, swap_exposed, self.now)

        # ---- estimator feedback + threshold update (§5.3)
        online_kv = self._online_kv_tokens()
        self.mem_pred.observe(self.now, online_kv)
        if self.policy.task_aware_kv:
            self.bm.threshold_blocks = self.mem_pred.threshold_blocks(
                self.bm.num_blocks, self.bm.block_size, online_kv,
                self.bm.clean_evictable_count())
            if self.bm.host is not None:
                # host-tier headroom for the predicted burst's swap-outs,
                # plus the slots whose payloads are still staging in flight
                self.bm.host.reserve = self.mem_pred.host_reserve_blocks(
                    self.bm.block_size, online_kv,
                    cap_blocks=self.bm.host.capacity,
                    inflight_blocks=(st.inflight_blocks()
                                     if st is not None else 0),
                    io=self.io)
        t_start = self.now - iter_time
        rec = IterationRecord(
            t=self.now,
            n_prefill=len(plan.prefills),
            n_decode=len(decodes),
            n_online=sum(1 for r in self.scheduler.running if r.is_online),
            n_offline=sum(1 for r in self.scheduler.running if not r.is_online),
            iter_time=iter_time,
            offline_tokens=offline_tokens,
            online_tokens=online_tokens,
            usage=self.bm.usage_breakdown(),
            hit_rate=self.bm.metrics.hit_rate,
            threshold_blocks=self.bm.threshold_blocks,
            swap_in_tokens=swap_in_tokens,
            swap_out_tokens=swap_out_tokens,
            swap_in_bytes=swap_in_bytes,
            swap_out_bytes=swap_out_bytes,
            host_blocks=len(self.bm.host) if self.bm.host is not None else 0,
            swap_transfer_time=swap_transfer,
            swap_exposed_time=swap_exposed,
            migrate_in_bytes=migrate_in_bytes,
        )
        self.stats.iterations.append(rec)
        base_hook = EngineListener.on_iteration
        detailed = [l for l in self.listeners
                    if type(l).on_iteration is not base_hook]
        if detailed:
            detail = IterationDetail(
                t_start=t_start, t_end=self.now,
                schedule_wall=schedule_wall,
                compute_time=compute_time,
                predicted_time=plan.est_time,
                admitted=plan.admitted,
                prefill_spans=[(r, s, e) for (r, _), (s, e)
                               in zip(plan.prefills, spans)],
                decodes=decodes)
            for l in detailed:
                l.on_iteration(rec, detail)
        return rec

    # ------------------------------------------------------------- loops
    def run(self, max_iters: int = 10_000,
            until_time: Optional[float] = None) -> EngineStats:
        stalls = 0
        for _ in range(max_iters):
            if until_time is not None and self.now >= until_time:
                break
            if not self.has_work():
                break
            rec = self.step()
            if rec is None and not self.pending:
                stalls += 1
                if stalls > MAX_STALLS:  # nothing schedulable: deadlock guard
                    break
            else:
                stalls = 0
        self.flush_swaps()             # land in-flight payloads before idle
        return self.stats
