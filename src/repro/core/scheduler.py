"""KV-cache-aware task scheduler (paper §4.1).

Per iteration the *plan generator* derives candidate batch configurations by
incremental edits to the last iteration's batch (the paper's search-space
collapse): continue running work, admit queued online requests FCFS
(preempting offline if needed), then — only once the online queue is fully
admitted (§6) — try offline admissions chosen by prefix-cache affinity and
length regularity. The *plan selector* scores candidates by
(Benefit - Punishment) / EstimatedTime (Eq.4) under the SLO (Eq. in §5.1)
and memory/threshold constraints, and commits the winner's allocations.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

from repro.core.block_manager import BlockManager
from repro.core.estimator import TimeModel
from repro.core.policies import PolicyConfig
from repro.core.radix_pool import OfflinePool
from repro.core.request import Request, RequestState, TaskType


@dataclass
class Plan:
    prefills: List[Tuple[Request, int]] = field(default_factory=list)  # (req, chunk)
    decodes: List[Request] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)
    swap_ins: List[Tuple[Request, int]] = field(default_factory=list)  # (req, tokens)
    admitted: List[Request] = field(default_factory=list)  # newly running
    est_time: float = 0.0
    benefit: float = 0.0
    punishment: float = 0.0

    @property
    def swap_in_tokens(self) -> int:
        return sum(n for _, n in self.swap_ins)

    @property
    def reward(self) -> float:
        if self.est_time <= 0:
            return 0.0
        return (self.benefit - self.punishment) / self.est_time

    @property
    def n_scheduled(self) -> int:
        return len(self.prefills) + len(self.decodes)


@dataclass
class _Candidate:
    """A tentative offline admission evaluated by the plan selector."""
    req: Request
    chunk: int
    cached: int                 # reusable prefix: device hits + host swap-in
    host_take: int              # tokens of ``cached`` restored over PCIe
    new_blocks: int
    punishment: float
    d_benefit: float
    d_time: float

    def score(self) -> float:
        # marginal reward per marginal second (Eq.4 on the increment)
        return (self.d_benefit - self.punishment) / max(self.d_time, 1e-9)


class Scheduler:
    def __init__(self, bm: BlockManager, pool: OfflinePool, tm: TimeModel,
                 policy: PolicyConfig, *,
                 chunk_size: int = 256,
                 max_batch_tokens: int = 2048,
                 max_running: int = 64,
                 offline_admit_per_iter: int = 1,   # §4.1: add the best ONE
                 slo_slack_factor: float = 0.9):
        self.bm = bm
        self.pool = pool
        self.tm = tm
        self.policy = policy
        self.chunk_size = chunk_size
        self.max_batch_tokens = max_batch_tokens
        self.max_running = max_running
        self.offline_admit_per_iter = offline_admit_per_iter
        self.slo_slack_factor = slo_slack_factor

        self.online_queue: Deque[Request] = deque()
        self.running: List[Request] = []
        self.last_plan: Optional[Plan] = None

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        if req.task_type == TaskType.ONLINE:
            self.online_queue.append(req)
        else:
            self.pool.add(req)

    # ------------------------------------------------------------- helpers
    def _blocks_for(self, req: Request, target_len: int) -> int:
        bs = self.bm.block_size
        have = len(req.block_ids)
        return max((target_len + bs - 1) // bs - have, 0)

    def _alloc(self, req: Request, target_len: int, now: float,
               respect_threshold: bool) -> bool:
        res = self.bm.allocate(req, target_len, req.full_tokens, now,
                               respect_threshold=respect_threshold)
        return res is not None

    def _restore_bytes(self, n_tokens: int) -> int:
        """Link bytes ONE swap-in entry of ``n_tokens`` puts on the PCIe
        stream, per the family's io spec: every restored KV page for paged
        attention, a single fixed-size snapshot (the last boundary) for
        restore_last_only state families."""
        return self.bm.io.restore_bytes(n_tokens, self.bm.block_size)

    def _swap_in_bytes(self, plan: Plan) -> int:
        """Byte weight of a plan's swap-in traffic. Priced per entry — each
        ``swap_ins`` element is one ``BlockManager.swap_in`` call, which
        journals exactly one non-lazy upload for a restore_last_only family
        — so this matches the engine's journal accounting 1:1."""
        return sum(self._restore_bytes(n) for _, n in plan.swap_ins)

    def _plan_transfer_time(self, swap_in_bytes: int) -> float:
        """Total PCIe seconds a plan carrying ``swap_in_bytes`` of swap-in
        traffic puts on the copy stream — including the swap-outs this
        scheduling pass already journaled (the engine clocks both
        directions)."""
        # NOTE: ``is not None`` — HostTier defines __len__, so a merely
        # *empty* tier is falsy while its journal can still carry undrained
        # swap-out events from this very scheduling pass
        out_bytes = (self.bm.pending_swap_out_bytes()
                     if self.bm.host is not None else 0)
        t = 0.0
        if swap_in_bytes:
            t += self.tm.swap_time(swap_in_bytes)
        if out_bytes:
            t += self.tm.swap_time(out_bytes)
        return t

    def _plan_time(self, spans, dlens, swap_in_bytes: int) -> float:
        """Iteration-time estimate for a (spans, decodes, swap-in) shape:
        compute overlapped with the plan's PCIe traffic — under overlap only
        the exposed transfer tail (plus the launch overhead) is charged on
        top of compute; with ``swap_overlap=False`` the serial sum."""
        compute = self.tm.batch_time(spans, dlens)
        return self.tm.overlapped_iteration_time(
            compute, self._plan_transfer_time(swap_in_bytes))

    def _swap_in_worthwhile(self, start: int, n_tokens: int,
                            plan: Optional[Plan] = None) -> bool:
        """The per-candidate transfer-vs-recompute decision: restoring
        ``n_tokens`` of cached state at context depth ``start`` over PCIe
        must beat re-prefilling the same span (Eq.6 increment). Priced in
        bytes through the family's io spec: with the default coefficients a
        paged-KV swap wins by ~20x on linear cost — but a deep-context
        span's quadratic term can tip either way — and a fixed-size state
        snapshot wins by orders of magnitude more, since its link cost does
        not grow with the restored span at all.

        Under swap/compute overlap a transfer that LOSES the raw seconds
        race gets a second chance at its *marginal iteration time*: hidden
        under the plan's compute it costs only the exposed tail, while
        recompute always grows the compute leg. The discount applies only
        when the restore displaces nothing (free blocks cover it): an
        eviction-funded restore churns future-needed blocks through the
        tier, and that displacement cost is real even when the link time is
        hidden — measured on the §7.1 burst scenario, undiscounted
        eviction-funded restores erase the entire overlap win."""
        serial_wins = (self.tm.swap_time(self._restore_bytes(n_tokens))
                       < self.tm.prefill_time([(start, start + n_tokens)]))
        if serial_wins or plan is None or not self.tm.swap_overlap:
            return serial_wins
        blocks = (n_tokens + self.bm.block_size - 1) // self.bm.block_size
        if self.bm.free_blocks < blocks:
            return False
        spans = [(r.computed_tokens, r.computed_tokens + c)
                 for r, c in plan.prefills]
        dlens = [r.total_len + 1 for r in plan.decodes]
        in_bytes = self._swap_in_bytes(plan)
        t_swap = self._plan_time(spans, dlens,
                                 in_bytes + self._restore_bytes(n_tokens))
        t_recompute = self._plan_time(spans + [(start, start + n_tokens)],
                                      dlens, in_bytes)
        return t_swap < t_recompute

    def _try_swap_in(self, req: Request, now: float, limit: int,
                     plan: Optional[Plan], respect_threshold: bool) -> int:
        """Restore a leading host-resident prefix instead of recomputing it.
        Returns tokens restored (0 if the tier is cold, the transfer would
        lose to recompute, or memory is exhausted). The restored span is
        charged as ``swap_time`` on the plan — it competes for the same SLO
        budget as compute."""
        if plan is None or self.bm.host is None:
            return 0
        bs = self.bm.block_size
        avail = self.bm.probe_host_prefix(req.full_tokens, req.computed_tokens)
        # keep >= 1 token to compute (logits for the next token), block-aligned
        avail = min(avail, limit - 1 - req.computed_tokens) // bs * bs
        if avail < bs:
            return 0
        if not self._swap_in_worthwhile(req.computed_tokens, avail, plan):
            return 0
        got = self.bm.swap_in(req, req.full_tokens, now, avail,
                              respect_threshold=respect_threshold)
        if got > 0:
            plan.swap_ins.append((req, got))
            req.computed_tokens += got
            req.swapped_in_tokens += got
        return got

    def _plan_prefill_chunk(self, req: Request, now: float,
                            respect_threshold: bool,
                            plan: Optional[Plan] = None) -> Optional[int]:
        """Allocate blocks for the next prefill chunk, skipping over blocks
        that turn out cached (leader/follower stagger: a same-prefix peer
        admitted one chunk behind hits every block its leader committed) and
        swapping in host-resident blocks when the transfer beats recompute.
        Returns the chunk length to compute (>=1) or None on memory failure.
        """
        limit = req.prefill_target_len
        bs = self.bm.block_size
        while True:
            if req.computed_tokens >= limit:
                return 0
            aligned = req.computed_tokens == len(req.block_ids) * bs
            if aligned and self._try_swap_in(req, now, limit, plan,
                                             respect_threshold) > 0:
                continue
            target = min(req.computed_tokens + self.chunk_size, limit)
            hits = self.bm.allocate(req, target, req.full_tokens, now,
                                    respect_threshold=respect_threshold)
            if hits is None:
                return None
            skip = min(hits, limit - 1 - req.computed_tokens) if aligned else 0
            if 0 < skip < hits:
                # fully-cached prompt: keep the resume point block-aligned
                # (state-snapshot runners resume only at block boundaries)
                skip = (req.computed_tokens + skip) // bs * bs \
                    - req.computed_tokens
            if skip > 0:
                req.computed_tokens += skip
                continue
            if self.policy.kv_aware_sched and \
                    self._leader_covers(req, req.computed_tokens, target):
                return 0          # a peer is computing this span: wait a turn
            return target - req.computed_tokens

    def _leader_covers(self, req: Request, start: int, end: int) -> bool:
        """True if another running request shares req's tokens on [start,end)
        and is about to compute that span itself — the follower should wait
        one iteration and then reuse the committed blocks instead of
        duplicating the prefix compute."""
        if req.task_type != TaskType.OFFLINE:
            return False
        toks = req.full_tokens
        for r2 in self.running:
            if r2 is req or r2.task_type != TaskType.OFFLINE or r2.prefill_done:
                continue
            c2 = r2.computed_tokens
            if not (start <= c2 < end):
                continue
            if c2 == start and r2.rid > req.rid:
                continue                      # tie: smaller rid leads
            span = min(end, len(r2.full_tokens))
            if span > start and r2.full_tokens[start:span] == toks[start:span]:
                return True
        return False

    def _preempt_request(self, victim: Request, now: float, plan: Plan) -> None:
        victim.n_preemptions += 1
        victim.state = RequestState.WAITING
        victim.computed_tokens = 0
        self.bm.free_request(victim, now, finished=False)
        if victim in self.running:
            self.running.remove(victim)
        plan.preempted.append(victim)
        plan.decodes = [r for r in plan.decodes if r is not victim]
        plan.prefills = [(r, c) for (r, c) in plan.prefills if r is not victim]
        # plan.swap_ins deliberately keeps the victim's entries: the PCIe
        # transfer already executed (blocks restored, journal staged), so
        # its time must still be charged; the restored blocks stay cached
        # for the victim's return
        self.pool.add(victim)                     # recompute mode: back to pool

    def _preempt_one_offline(self, now: float, plan: Plan) -> bool:
        """Evict the most-recently-admitted running offline request."""
        victims = [r for r in self.running
                   if r.task_type == TaskType.OFFLINE and r not in plan.preempted]
        if not victims:
            return False
        self._preempt_request(victims[-1], now, plan)
        return True

    def _preempt_one_online(self, now: float, plan: Plan,
                            exclude: Request) -> bool:
        """Memory-full fallback (vLLM recompute preemption): the latest
        arrived running online request yields so earlier ones can progress;
        it returns to the online queue head group by arrival order."""
        victims = [r for r in self.running
                   if r.is_online and r is not exclude and r not in plan.preempted]
        if not victims:
            return False
        victim = max(victims, key=lambda r: (r.arrival_time, r.rid))
        victim.n_preemptions += 1
        victim.state = RequestState.WAITING
        victim.computed_tokens = 0
        self.bm.free_request(victim, now, finished=False)
        self.running.remove(victim)
        plan.preempted.append(victim)
        plan.decodes = [r for r in plan.decodes if r is not victim]
        plan.prefills = [(r, c) for (r, c) in plan.prefills if r is not victim]
        self.online_queue.appendleft(victim)
        return True

    def _slo_budget(self, now: float, plan: Plan) -> float:
        budget = float("inf")
        for req in plan.decodes + [r for r, _ in plan.prefills]:
            if req.is_online:
                b = req.latency_budget(now)
                if b <= 0 and req.slo is not None:
                    # already late: the deadline is sunk — pace at TPOT so
                    # the batch keeps moving instead of starving forever
                    b = req.slo.tpot
                budget = min(budget, b)
        return budget * self.slo_slack_factor

    def _expected_punishment(self, n_evictions: int) -> float:
        """Expected cost (in recompute-token units) of the next n evictions.

        Uses ``BlockManager.peek_eviction_order`` — the same lazy-heap
        discipline eviction realizes — instead of an independent sort that
        could disagree with it. A future-needed block the host tier will
        absorb is punished at its (much cheaper) swap-round-trip equivalent,
        never more than the full recompute it replaces."""
        if n_evictions <= 0:
            return 0.0
        if not self.policy.task_aware_kv and not self.policy.kv_aware_sched:
            return 0.0
        pun = 0.0
        for b in self.bm.peek_eviction_order(n_evictions):
            rc = self.bm.rc_provider(b.hash) + b.unfinished_owners
            if rc > 0:
                if self.bm.would_swap(self.bm._priority(b)):
                    # round trip priced in the block's actual link weight
                    # (KV pages or one fixed-size snapshot), capped at the
                    # full recompute the host tier saves
                    pun += min(self.tm.swap_equiv_tokens(
                        self.bm.io.block_bytes(b.n_tokens)),
                        float(b.n_tokens))
                else:
                    pun += b.n_tokens
        return pun

    def _plan_tokens(self, plan: Plan) -> int:
        return sum(c for _, c in plan.prefills) + len(plan.decodes)

    def _estimate(self, plan: Plan) -> float:
        # PCIe traffic competes for the SLO budget — but under overlap only
        # its exposed tail does; ``_plan_time`` charges planned swap-ins and
        # already-journaled swap-outs either way
        spans = [(r.computed_tokens, r.computed_tokens + c)
                 for r, c in plan.prefills]
        dlens = [r.total_len + 1 for r in plan.decodes]
        return self._plan_time(spans, dlens, self._swap_in_bytes(plan))

    # ------------------------------------------------------------- schedule
    def schedule(self, now: float) -> Plan:
        plan = Plan()

        # 1. base plan = last batch, minus finished: continue decodes/prefills
        self.running = [r for r in self.running
                        if r.state == RequestState.RUNNING]
        for req in list(self.running):
            if req.prefill_done:
                if not req.done:
                    plan.decodes.append(req)
            else:
                chunk = self._plan_prefill_chunk(
                    req, now, respect_threshold=not req.is_online, plan=plan)
                while chunk is None and req.is_online and \
                        self._preempt_one_offline(now, plan):
                    chunk = self._plan_prefill_chunk(req, now,
                                                     respect_threshold=False,
                                                     plan=plan)
                if chunk is None:
                    if req.task_type == TaskType.OFFLINE:
                        self._preempt_request(req, now, plan)
                    continue
                if chunk > 0:
                    plan.prefills.append((req, chunk))
                elif req.prefill_done and not req.done:  # fully cached: decode
                    plan.decodes.append(req)
                # else: waiting on a leader to commit the shared span

        # 2. admit online FCFS, preempting offline on memory pressure
        while self.online_queue:
            req = self.online_queue[0]
            if len(self.running) >= self.max_running:
                # slots full: offline yields its seat to online (priority)
                if not self._preempt_one_offline(now, plan):
                    break
                continue
            req.admit(now)
            chunk = self._plan_prefill_chunk(req, now, respect_threshold=False,
                                             plan=plan)
            while chunk is None and self._preempt_one_offline(now, plan):
                chunk = self._plan_prefill_chunk(req, now,
                                                 respect_threshold=False,
                                                 plan=plan)
            if chunk is None:
                req.state = RequestState.WAITING
                self.bm.free_request(req, now, finished=False)
                req.computed_tokens = 0
                break
            # §6: online admission is also SLO-gated — adding this prefill
            # must not blow the batch budget of already-running requests
            # (the queued request's own TTFT slack covers the wait)
            if self.policy.use_estimator and chunk > 0 and plan.n_scheduled:
                trial = Plan(prefills=plan.prefills + [(req, chunk)],
                             decodes=plan.decodes, swap_ins=plan.swap_ins)
                if self._estimate(trial) > self._slo_budget(now, trial):
                    req.state = RequestState.WAITING
                    self.bm.free_request(req, now, finished=False)
                    req.computed_tokens = 0
                    break
            self.online_queue.popleft()
            self.running.append(req)
            plan.admitted.append(req)
            if chunk > 0:
                plan.prefills.append((req, chunk))

        # decode slots for continuing decodes (may preempt offline, then —
        # memory-full fallback — later-arrived online)
        kept = []
        for req in plan.decodes:
            ok = self._alloc(req, req.total_len + 1, now,
                             respect_threshold=not req.is_online)
            while not ok and req.is_online and (
                    self._preempt_one_offline(now, plan)
                    or self._preempt_one_online(now, plan, req)):
                ok = self._alloc(req, req.total_len + 1, now,
                                 respect_threshold=False)
            if ok:
                kept.append(req)
            elif req.task_type == TaskType.OFFLINE:
                # cannot grow: preempt it (frees its own blocks)
                req.n_preemptions += 1
                req.state = RequestState.WAITING
                req.computed_tokens = 0
                self.bm.free_request(req, now, finished=False)
                self.running.remove(req)
                plan.preempted.append(req)
                self.pool.add(req)
        # a later decode's alloc may have preempted an EARLIER one already
        # moved into ``kept`` — restoring it here would emit a ghost token
        # for a request whose blocks are freed and that sits back in the
        # queue (it could even "finish" there and later finish again)
        plan.decodes = [r for r in kept if r not in plan.preempted]

        # 3. SLO feasibility of the mandatory part: shed offline work.
        # Shedding removes the chunk from the plan AND rolls its freshly
        # allocated blocks back to the computed-token boundary — otherwise
        # the request keeps holding blocks for work it won't do this
        # iteration, inflating running_blocks/depleting free memory for
        # same-iteration offline admission.
        budget = self._slo_budget(now, plan)
        if self.policy.use_estimator:
            while self._estimate(plan) > budget:
                off_pf = [(r, c) for r, c in plan.prefills
                          if r.task_type == TaskType.OFFLINE]
                if off_pf:
                    r, c = off_pf[-1]
                    plan.prefills.remove((r, c))
                    self.bm.trim_request(r, r.computed_tokens, now)
                    continue
                off_dec = [r for r in plan.decodes
                           if r.task_type == TaskType.OFFLINE]
                if off_dec:
                    r = off_dec[-1]
                    plan.decodes.remove(r)             # skip this iteration
                    self.bm.trim_request(r, r.computed_tokens, now)
                    continue
                break

        # 4. offline admission (only when the online queue is drained, §6)
        if not self.online_queue:
            self._admit_offline(now, plan, budget)

        # 5. finalize
        plan.benefit = float(self._plan_tokens(plan))
        plan.est_time = self._estimate(plan)
        self.last_plan = plan
        return plan

    # ------------------------------------------------------------- offline
    def _offline_candidates(self, now: float) -> List[Request]:
        if not self.policy.kv_aware_sched:
            head = self.pool.fcfs_head()
            return [head] if head is not None else []
        return list(self.pool.candidates())

    def _evaluate_candidate(self, req: Request, plan: Plan) -> _Candidate:
        tokens = req.full_tokens
        bs = self.bm.block_size
        dev_cached = self.bm.probe_prefix(tokens)
        # swap-in-vs-recompute, priced per candidate: a host-resident prefix
        # extends the reusable prefix at PCIe cost instead of compute cost
        host_take = 0
        host_avail = self.bm.probe_host_prefix(tokens, dev_cached)
        if host_avail:
            cap = max(len(tokens) - 1 - dev_cached, 0) // bs * bs
            host_take = min(host_avail, cap)
            if host_take and not self._swap_in_worthwhile(dev_cached,
                                                          host_take, plan):
                host_take = 0
        cached = min(dev_cached + host_take, max(len(tokens) - 1, 0))
        chunk = min(len(tokens) - cached, self.chunk_size)
        new_blocks = self._blocks_for(req, cached + chunk)
        free = self.bm.free_blocks
        evictions = max(new_blocks - free, 0)
        pun = self._expected_punishment(evictions)
        base_spans = [(r.computed_tokens, r.computed_tokens + c)
                      for r, c in plan.prefills]
        dlens = [r.total_len + 1 for r in plan.decodes]
        t0 = self.tm.batch_time(base_spans, dlens)
        t1 = self.tm.batch_time(base_spans + [(cached, cached + chunk)], dlens)
        # Eq.4's denominator is resource occupancy, not latency: the
        # host_take's transfer holds the PCIe link for its full serial time
        # even when the clock hides it under compute, so candidate scoring
        # charges it undiscounted — otherwise hidden restores score near
        # infinity, crowd out cache-hit admissions, and the eviction churn
        # costs more than the hidden seconds saved. The overlap discount
        # lives where latency is the question: ``est_time``/the SLO budget
        # (``_estimate``) and the execution clock.
        d_time = t1 - t0 + self.tm.swap_time(self._restore_bytes(host_take))
        # benefit counts the *progress* incl. reused prefix (recompute avoided)
        d_benefit = float(chunk + cached) if req.computed_tokens == 0 else float(chunk)
        return _Candidate(req, chunk, cached, host_take, new_blocks, pun,
                          d_benefit, d_time)

    def _first_hash(self, req: Request) -> Optional[int]:
        from repro.core.block_manager import chain_hash
        bs = self.bm.block_size
        if len(req.prompt) < bs:
            return None
        return chain_hash(0, tuple(req.prompt[:bs]))

    def _admit_offline(self, now: float, plan: Plan, budget: float) -> None:
        admitted = 0
        # prefix groups whose leader was JUST admitted (nothing committed
        # yet): a peer admitted in the same iteration would recompute the
        # prefix in parallel. Once the leader has committed >= 1 block,
        # followers trail it chunk-by-chunk and reuse its blocks (§4.1
        # Fig.4b stagger).
        bs = self.bm.block_size
        shadow = {self._first_hash(r) for r in self.running
                  if r.task_type == TaskType.OFFLINE and not r.prefill_done
                  and r.computed_tokens < bs}
        shadow.discard(None)
        while admitted < self.offline_admit_per_iter and len(self.pool):
            if len(self.running) >= self.max_running:
                break
            if self._plan_tokens(plan) >= self.max_batch_tokens:
                break
            pool_cands = list(self._offline_candidates(now))
            if self.policy.kv_aware_sched and shadow:
                unshadowed = [r for r in pool_cands
                              if self._first_hash(r) not in shadow]
                if unshadowed or plan.prefills:
                    pool_cands = unshadowed
            cands = [self._evaluate_candidate(r, plan) for r in pool_cands]
            cands = [c for c in cands if c.chunk > 0]
            if not cands:
                break
            if self.policy.kv_aware_sched:
                # regularity tie-break: prefer candidates whose length matches
                # the batch's running mean (paper §4.1 "balanced length")
                cands.sort(key=lambda c: -c.score())
            best = cands[0]
            req = best.req
            # constraints: memory (threshold-respecting) + SLO — including
            # the PCIe time of any swap-in the candidate's plan relies on
            trial_spans = ([(r.computed_tokens, r.computed_tokens + c)
                            for r, c in plan.prefills]
                           + [(best.cached, best.cached + best.chunk)])
            dlens = [r.total_len + 1 for r in plan.decodes]
            t_new = self._plan_time(
                trial_spans, dlens,
                self._swap_in_bytes(plan) + self._restore_bytes(best.host_take))
            if self.policy.use_estimator and t_new > budget:
                break
            req.admit(now)
            chunk = self._plan_prefill_chunk(req, now, respect_threshold=True,
                                             plan=plan)
            if chunk is None:
                req.state = RequestState.WAITING
                self.bm.free_request(req, now, finished=False)
                req.computed_tokens = 0
                break
            self.pool.remove(req)
            self.running.append(req)
            plan.admitted.append(req)
            if chunk > 0:
                plan.prefills.append((req, chunk))
                if not req.prefill_done:
                    shadow.add(self._first_hash(req))   # new prefix leader
            elif req.prefill_done:
                plan.decodes.append(req)
            plan.punishment += best.punishment
            admitted += 1
