"""The evaluation ablation lattice (§7.1).

BS        : vLLM + priority scheduling (online preempts offline), FCFS
            offline order, plain-LRU free table, no SLO estimator.
BS+E      : + execution-time estimator gating batch growth by online SLOs.
BS+E+S    : + KV-cache-aware offline selection (prefix affinity, length
            regularity, last-batch incremental plan search).
Echo      : + task-aware KV cache manager (priority eviction + burst
            threshold from the memory predictor).
Echo+C    : + online calibration — the scheduler's time model is refit
            against the observed (ground-truth) clock when it drifts.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PolicyConfig:
    name: str
    use_estimator: bool      # SLO-aware admission (E)
    kv_aware_sched: bool     # prefix/regularity-aware offline selection (S)
    task_aware_kv: bool      # priority eviction + threshold (M)
    calibrate: bool = False  # online refit of the time model (C)


BS = PolicyConfig("BS", False, False, False)
BS_E = PolicyConfig("BS+E", True, False, False)
BS_E_S = PolicyConfig("BS+E+S", True, True, False)
ECHO = PolicyConfig("Echo", True, True, True)
ECHO_C = PolicyConfig("Echo+C", True, True, True, calibrate=True)

ALL_POLICIES = (BS, BS_E, BS_E_S, ECHO)
