"""Echo estimation toolkits (§5): execution-time model, memory predictor,
online-trace rate predictor.

Time model (Eq. 6-8):
    T_prefill(l)  = max(alpha * l^2 + beta * l, c)
    T_decode(L)   = gamma * max(L) + delta * mean(L)
    T_batch       = lam * max(Tp, Td) + (1 - lam) * min(Tp, Td)

Coefficients are fit from micro-benchmark samples with non-negative least
squares (simple projected lstsq). For SSM/RG-LRU families prefill cost is
linear: the quadratic basis column is dropped (alpha pinned to 0).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

from repro.core.block_io import (  # noqa: F401  (KV_* kept as re-export)
    KV_BYTES_PER_TOKEN_8B,
    BlockIOSpec,
)


@dataclass
class TimeModel:
    alpha: float = 1e-9      # s / token^2  (prefill quadratic)
    beta: float = 1e-6       # s / token    (prefill linear)
    c: float = 1e-4          # s            (prefill floor)
    gamma: float = 1e-7     # s / token    (decode max-pool)
    delta: float = 1e-7      # s / token    (decode mean-pool)
    d0: float = 1e-4         # s            (decode floor)
    lam: float = 0.8         # prefill/decode overlap coefficient
    swap_byte: float = 0.0   # s / byte     (host<->device payload over PCIe)
    swap_floor: float = 0.0  # s            (per-transfer dispatch floor)
    swap_launch: float = 0.0  # s           (async copy launch/fence overhead)
    swap_overlap: bool = True  # overlap PCIe transfers with compute (Eq.9)
    migrate_byte: float = 0.0   # s / byte  (replica->replica over the fabric)
    migrate_floor: float = 0.0  # s         (per-migration connection setup)
    quadratic_prefill: bool = True

    @classmethod
    def a100(cls, **overrides) -> "TimeModel":
        """Coefficients of LLaMA-3.1-8B-instruct magnitude on one A100-40G,
        structured per Eq.6-8 — the shared default for virtual-clock serving,
        cluster simulation, benchmarks, and examples. Swap terms price PCIe
        4.0 x16 (~25 GB/s effective); what a block *weighs* comes from the
        runner family's ``BlockIOSpec``, not from the link model."""
        kw = dict(alpha=2e-7, beta=1e-4, c=2e-3, gamma=3e-5, delta=3e-5,
                  d0=2e-3, lam=0.9,
                  swap_byte=cls.pcie_swap_byte(25.0), swap_floor=1e-4,
                  swap_launch=5e-5,
                  migrate_byte=cls.pcie_swap_byte(10.0), migrate_floor=2e-4)
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def h100(cls, **overrides) -> "TimeModel":
        """H100-80G magnitude: ~2.5x the A100 FLOPs and ~1.7x its HBM
        bandwidth, so the quadratic attention term shrinks more than the
        bandwidth-bound decode terms; floors shrink with faster dispatch.
        PCIe 5.0 x16 doubles the swap bandwidth (~50 GB/s effective)."""
        kw = dict(alpha=8e-8, beta=4e-5, c=1e-3, gamma=1.8e-5, delta=1.8e-5,
                  d0=1.2e-3, lam=0.92,
                  swap_byte=cls.pcie_swap_byte(50.0), swap_floor=5e-5,
                  swap_launch=2e-5,
                  migrate_byte=cls.pcie_swap_byte(25.0), migrate_floor=1e-4)
        kw.update(overrides)
        return cls(**kw)

    @staticmethod
    def pcie_swap_byte(pcie_gbps: float) -> float:
        """Per-byte host<->device transfer seconds from link bandwidth."""
        return 1.0 / (pcie_gbps * 1e9)

    HW_PROFILES = ("a100", "h100")

    @classmethod
    def preset(cls, name: str, **overrides) -> "TimeModel":
        if name not in cls.HW_PROFILES:
            raise ValueError(f"unknown hardware profile {name!r}; "
                             f"expected one of {cls.HW_PROFILES}")
        return getattr(cls, name)(**overrides)

    def perturbed(self, scale: float = 1.0, jitter: float = 0.0,
                  contention_prob: float = 0.0, contention_scale: float = 2.0,
                  seed: int = 0) -> "PerturbedTimeModel":
        """Ground-truth wrapper: this model's Eq.6-8 structure, scaled by a
        systematic miscalibration ``scale`` plus seeded per-iteration noise."""
        return PerturbedTimeModel(base=self, scale=scale, jitter=jitter,
                                  contention_prob=contention_prob,
                                  contention_scale=contention_scale, seed=seed)

    # ------------------------------------------------------------ queries
    def prefill_time(self, spans: Sequence[Tuple[int, int]]) -> float:
        """Prefill chunks are processed one by one (§5.2).

        Each span (s, e) is the token range computed this iteration; the
        quadratic attention term for a chunk of a longer context is the
        increment alpha*(e^2 - s^2), consistent with Eq.6 for (0, l).
        """
        t = 0.0
        for s, e in spans:
            t += max(self.alpha * (e * e - s * s) + self.beta * (e - s), self.c)
        return t

    def decode_time(self, lens: Sequence[int]) -> float:
        if len(lens) == 0:
            return 0.0
        return max(self.gamma * max(lens) + self.delta * float(np.mean(lens)),
                   self.d0)

    def batch_time(self, prefill_spans: Sequence[Tuple[int, int]],
                   decode_lens: Sequence[int]) -> float:
        tp = self.prefill_time(prefill_spans) if prefill_spans else 0.0
        td = self.decode_time(decode_lens) if decode_lens else 0.0
        if tp == 0.0 or td == 0.0:
            return tp + td
        return self.lam * max(tp, td) + (1.0 - self.lam) * min(tp, td)

    def swap_time(self, n_bytes: int) -> float:
        """Host<->device transfer time for ``n_bytes`` of block payload over
        PCIe — the cost side of the swap-in-vs-recompute decision, and the
        term charged against the SLO budget when a plan carries swap traffic.
        Callers convert blocks to bytes through the runner family's
        ``BlockIOSpec`` so paged KV pages and fixed-size state snapshots are
        charged by what they actually move."""
        if n_bytes <= 0:
            return 0.0
        return self.swap_byte * n_bytes + self.swap_floor

    def migrate_time(self, n_bytes: int) -> float:
        """Replica-to-replica transfer time for ``n_bytes`` of parked prefix
        payload over the inter-node fabric — the price of shipping a host-tier
        block to the replica the router steals toward, instead of recomputing
        the prefix there. Typically slower per byte than the local PCIe hop
        (``swap_byte``) and with a higher connection-setup floor."""
        if n_bytes <= 0:
            return 0.0
        return self.migrate_byte * n_bytes + self.migrate_floor

    def swap_equiv_tokens(self, n_bytes: int, trips: int = 2) -> float:
        """A swap expressed in recompute-token units (Eq.4's benefit and
        punishment are token-denominated): transfer seconds divided by the
        linear prefill cost per token. Defaults to the full round trip
        (``trips=2``, out now + in later) — what evicting a future-needed
        block to the host tier costs instead of its recompute."""
        return trips * self.swap_time(n_bytes) / max(self.beta, 1e-12)

    def overlapped_iteration_time(self, compute: float,
                                  transfer: float) -> float:
        """Iteration time when PCIe transfers run on an async copy stream:
        ``max(compute, transfer)`` plus the launch/fence overhead of kicking
        the stream, instead of the serial ``compute + transfer``. With
        ``swap_overlap=False`` this degrades to the serial charge exactly
        (the pre-overlap clock)."""
        if transfer <= 0.0:
            return compute
        if not self.swap_overlap:
            return compute + transfer
        return max(compute, transfer) + self.swap_launch

    def exposed_swap_time(self, compute: float, transfer: float) -> float:
        """The transfer tail NOT hidden under compute — the only part of the
        PCIe traffic that counts against the SLO budget under overlap."""
        return self.overlapped_iteration_time(compute, transfer) - compute

    # ------------------------------------------------------------ fitting
    def fit_prefill(self, samples: Sequence[Tuple]) -> None:
        """samples: (prompt_len, seconds) for single-prefill iterations, or
        ((start, end), seconds) for mid-context chunks — the quadratic basis
        of a span (s, e) is its attention increment e^2 - s^2 (see
        ``prefill_time``), so both forms fit the same Eq.6 coefficients.

        Fit with an intercept column: on hosts where small-prefill cost is
        dominated by a dispatch floor (flat timings), an intercept-free
        quadratic fit extrapolates garbage; Eq.6's `c` absorbs the floor."""
        if len(samples) < 3:
            return
        spans = [(0, x) if np.isscalar(x) else tuple(x)
                 for x, _ in samples]
        quad = np.array([e * e - s * s for s, e in spans], np.float64)
        ls = np.array([e - s for s, e in spans], np.float64)
        ts = np.array([t for _, t in samples], np.float64)
        ones = np.ones_like(ls)
        if self.quadratic_prefill:
            basis = np.stack([quad, ls, ones], axis=1)
        else:
            basis = np.stack([ls, ones], axis=1)
        coef, *_ = np.linalg.lstsq(basis, ts, rcond=None)
        coef = np.maximum(coef, 0.0)
        if self.quadratic_prefill:
            self.alpha, self.beta, c = map(float, coef)
        else:
            self.alpha = 0.0
            self.beta, c = map(float, coef)
        self.c = float(max(min(np.min(ts), max(c, 1e-6)), 1e-6))

    def fit_decode(self, samples: Sequence[Tuple[int, float, float]]) -> None:
        """samples: (max_len, mean_len, seconds) for decode-only batches."""
        if len(samples) < 3:
            return
        mx = np.array([s[0] for s in samples], np.float64)
        mn = np.array([s[1] for s in samples], np.float64)
        ts = np.array([s[2] for s in samples], np.float64)
        basis = np.stack([mx, mn, np.ones_like(mx)], axis=1)   # + floor
        coef, *_ = np.linalg.lstsq(basis, ts, rcond=None)
        coef = np.maximum(coef, 0.0)
        self.gamma, self.delta = float(coef[0]), float(coef[1])
        self.d0 = float(max(min(np.min(ts), max(float(coef[2]), 1e-6)), 1e-6))

    def fit_swap(self, samples: Sequence[Tuple[int, float]]) -> None:
        """samples: (n_bytes, seconds) for host<->device block transfers —
        micro-benchmarked like Eq.6-8 (calibration support for the PCIe
        terms; a fit on real ``jax.device_put`` timings replaces the link
        presets). Byte-denominated, so KV-page and state-snapshot payloads
        land in one pool and jointly recover the link rate."""
        if len(samples) < 2:
            return
        ns = np.array([s[0] for s in samples], np.float64)
        ts = np.array([s[1] for s in samples], np.float64)
        basis = np.stack([ns, np.ones_like(ns)], axis=1)
        coef, *_ = np.linalg.lstsq(basis, ts, rcond=None)
        coef = np.maximum(coef, 0.0)
        self.swap_byte = float(coef[0])
        self.swap_floor = float(max(min(np.min(ts), max(float(coef[1]), 0.0)),
                                    0.0))

    def fit_migrate(self, samples: Sequence[Tuple[int, float]]) -> None:
        """samples: (n_bytes, seconds) for replica->replica prefix shipments —
        the inter-node analogue of ``fit_swap``; recovers the fabric rate and
        the per-migration setup floor from observed timings."""
        if len(samples) < 2:
            return
        ns = np.array([s[0] for s in samples], np.float64)
        ts = np.array([s[1] for s in samples], np.float64)
        basis = np.stack([ns, np.ones_like(ns)], axis=1)
        coef, *_ = np.linalg.lstsq(basis, ts, rcond=None)
        coef = np.maximum(coef, 0.0)
        self.migrate_byte = float(coef[0])
        self.migrate_floor = float(max(min(np.min(ts),
                                           max(float(coef[1]), 0.0)), 0.0))

    def fit_swap_overlap(self, samples: Sequence[Tuple[float, int, float]]) -> None:
        """samples: (compute_seconds, transfer_bytes, total_seconds) for
        iterations that carried overlapped swap traffic. Fits the launch
        overhead as the median residual of the max-model — robust to the odd
        iteration where a fence exposed a partial tail."""
        resid = [t - max(c, self.swap_time(n))
                 for c, n, t in samples if n > 0]
        if len(resid) < 2:
            return
        resid.sort()
        self.swap_launch = float(max(resid[len(resid) // 2], 0.0))

    def fit_lambda(self, samples: Sequence[Tuple[float, float, float]]) -> None:
        """samples: (t_prefill_est, t_decode_est, seconds) for mixed batches."""
        if not samples:
            return
        num, den = 0.0, 0.0
        for tp, td, t in samples:
            hi, lo = max(tp, td), min(tp, td)
            if hi - lo < 1e-12:
                continue
            num += (t - lo) * (hi - lo)
            den += (hi - lo) ** 2
        if den > 0:
            self.lam = float(min(max(num / den, 0.0), 1.5))


@dataclass
class PerturbedTimeModel:
    """Ground-truth execution clock distinct from the scheduler's estimate.

    Wraps a base ``TimeModel`` (the true hardware profile) with a systematic
    miscalibration factor, seeded multiplicative log-normal jitter, and rare
    contention spikes (a neighbour stealing the GPU for one iteration).
    ``batch_time`` is stateful — each call draws fresh noise — so it must
    only clock execution, never score scheduling candidates."""
    base: TimeModel
    scale: float = 1.0              # systematic drift vs. the estimate
    jitter: float = 0.0             # sigma of per-iteration log-normal noise
    contention_prob: float = 0.0    # chance an iteration hits contention
    contention_scale: float = 2.0   # slowdown of a contended iteration
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def mean_time(self, prefill_spans: Sequence[Tuple[int, int]],
                  decode_lens: Sequence[int]) -> float:
        """Noise-free expected iteration time (for analysis/tests)."""
        return self.base.batch_time(prefill_spans, decode_lens) * self.scale

    def batch_time(self, prefill_spans: Sequence[Tuple[int, int]],
                   decode_lens: Sequence[int]) -> float:
        t = self.mean_time(prefill_spans, decode_lens)
        if self.jitter > 0.0:
            t *= float(self._rng.lognormal(0.0, self.jitter))
        if self.contention_prob > 0.0 and \
                self._rng.random() < self.contention_prob:
            t *= self.contention_scale
        return t

    def swap_time(self, n_bytes: int) -> float:
        """PCIe transfers share the systematic drift but not the compute
        jitter (the link is not the contended resource). Byte-denominated,
        passed straight through to the base model's byte terms."""
        return self.base.swap_time(n_bytes) * self.scale

    def migrate_time(self, n_bytes: int) -> float:
        """Inter-node fabric hops drift with the same systematic scale as
        the PCIe terms (one miscalibrated hardware profile)."""
        return self.base.migrate_time(n_bytes) * self.scale

    @property
    def swap_overlap(self) -> bool:
        return self.base.swap_overlap

    @property
    def swap_launch(self) -> float:
        return self.base.swap_launch * self.scale

    def overlapped_iteration_time(self, compute: float,
                                  transfer: float) -> float:
        """Same max-plus-launch structure as the base model; ``compute`` and
        ``transfer`` arrive already drifted/jittered by this wrapper, so only
        the launch overhead picks up the systematic scale here."""
        if transfer <= 0.0:
            return compute
        if not self.base.swap_overlap:
            return compute + transfer
        return max(compute, transfer) + self.swap_launch

    def exposed_swap_time(self, compute: float, transfer: float) -> float:
        return self.overlapped_iteration_time(compute, transfer) - compute


@dataclass
class DegradedClock:
    """Straggler wrapper for a ground-truth clock: every ground-truth term —
    compute, PCIe, fabric, launch — runs ``slowdown``x slower than the
    wrapped clock (a thermally throttled or noisy-neighbour replica).

    Composable over either a plain ``TimeModel`` or a ``PerturbedTimeModel``;
    it never touches the scheduler's *estimate*, so a degraded replica keeps
    planning as if healthy and the damage shows up as clock skew — exactly
    the signal the router's ``predicted_added_latency`` already penalizes."""
    base: object                    # TimeModel | PerturbedTimeModel
    slowdown: float = 2.0

    def mean_time(self, prefill_spans: Sequence[Tuple[int, int]],
                  decode_lens: Sequence[int]) -> float:
        mean = getattr(self.base, "mean_time", None)
        t = (mean(prefill_spans, decode_lens) if mean is not None
             else self.base.batch_time(prefill_spans, decode_lens))
        return t * self.slowdown

    def batch_time(self, prefill_spans: Sequence[Tuple[int, int]],
                   decode_lens: Sequence[int]) -> float:
        return self.base.batch_time(prefill_spans, decode_lens) * self.slowdown

    def swap_time(self, n_bytes: int) -> float:
        return self.base.swap_time(n_bytes) * self.slowdown

    def migrate_time(self, n_bytes: int) -> float:
        return self.base.migrate_time(n_bytes) * self.slowdown

    @property
    def swap_overlap(self) -> bool:
        return self.base.swap_overlap

    @property
    def swap_launch(self) -> float:
        return self.base.swap_launch * self.slowdown

    def overlapped_iteration_time(self, compute: float,
                                  transfer: float) -> float:
        """``compute``/``transfer`` arrive already slowed by this wrapper,
        so only the launch overhead picks up the slowdown here."""
        if transfer <= 0.0:
            return compute
        if not self.swap_overlap:
            return compute + transfer
        return max(compute, transfer) + self.swap_launch

    def exposed_swap_time(self, compute: float, transfer: float) -> float:
        return self.overlapped_iteration_time(compute, transfer) - compute


@dataclass
class MemoryPredictor:
    """§5.3: predict online KV demand as mu + k*sigma over a sliding window."""
    window: float = 3600.0          # seconds of history
    k_sigma: float = 2.0
    _obs: Deque[Tuple[float, float]] = field(default_factory=deque)
    # running first/second moments of the window so predict() is O(1):
    # callers (threshold + host reserve + the drift probes) hit it several
    # times per engine iteration and the window can hold thousands of
    # samples
    _sum: float = 0.0
    _sumsq: float = 0.0

    def observe(self, now: float, online_kv_tokens: float) -> None:
        self._obs.append((now, online_kv_tokens))
        self._sum += online_kv_tokens
        self._sumsq += online_kv_tokens * online_kv_tokens
        cutoff = now - self.window
        while self._obs and self._obs[0][0] < cutoff:
            _, v = self._obs.popleft()
            self._sum -= v
            self._sumsq -= v * v

    def predict(self) -> float:
        n = len(self._obs)
        if n == 0:
            return 0.0
        mean = self._sum / n
        var = max(self._sumsq / n - mean * mean, 0.0)
        return float(mean + self.k_sigma * math.sqrt(var))

    def threshold_blocks(self, total_blocks: int, block_size: int,
                         current_online_tokens: float = 0.0,
                         clean_evictable_blocks: int = 0,
                         floor_frac: float = 0.5) -> int:
        """Running-KV cap (the §4.2 threshold): reserve headroom for the
        predicted *increment* of online KV demand over what is resident,
        net of blocks a burst may already evict punishment-free (dead
        offline / finished online — evicting those costs nothing)."""
        inc = max(self.predict() - current_online_tokens, 0.0)
        reserve = max(int(math.ceil(inc / block_size)) - clean_evictable_blocks, 0)
        return max(total_blocks - reserve, int(total_blocks * floor_frac))

    def host_reserve_blocks(self, block_size: int,
                            current_online_tokens: float = 0.0,
                            cap_blocks: Optional[int] = None,
                            inflight_blocks: int = 0,
                            io: Optional[BlockIOSpec] = None) -> int:
        """Host-tier headroom (§5.3 applied to the swap layer): slots to
        keep clear of low-priority swaps so a predicted online burst can
        always park the state it preempts instead of losing it to recompute.
        With an ``io`` spec the burst is priced in bytes and converted back
        through the family's per-slot payload — a host slot holds one full
        block, whatever that block weighs (KV pages or one fixed-size state
        snapshot) — so paged and state families reserve uniformly.
        ``inflight_blocks`` — swap payloads still staging on the async copy
        stream — extend the reserve: a slot whose transfer has not landed
        cannot be re-purposed without losing the work in flight."""
        inc = max(self.predict() - current_online_tokens, 0.0)
        inc_blocks = int(math.ceil(inc / block_size))
        if io is not None:
            slot_bytes = max(io.block_bytes(block_size), 1)
            inc_bytes = inc_blocks * io.block_bytes(block_size)
            inc_blocks = int(math.ceil(inc_bytes / slot_bytes))
        reserve = inc_blocks + max(inflight_blocks, 0)
        if cap_blocks is not None:
            reserve = min(reserve, cap_blocks // 2)
        return reserve


@dataclass
class RatePredictor:
    """Fig.11: predict online arrival rate from a sliding window
    (mu + k*sigma, k=2 to cover ~95% of bursts, §5.3)."""
    window: float = 900.0
    k_sigma: float = 2.0
    _arrivals: Deque[float] = field(default_factory=deque)
    _t0: Optional[float] = None          # first observation: history start

    def observe(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t
        self._arrivals.append(t)
        cutoff = t - self.window
        while self._arrivals and self._arrivals[0] < cutoff:
            self._arrivals.popleft()

    def predict_rate(self, now: float, bin_s: float = 60.0) -> float:
        """Predicted arrivals/s = mu + sigma of per-bin counts, binned only
        over *elapsed* history: during warmup (observed span < window) bins
        before the first observation would be structurally empty and dilute
        the rate ~window/elapsed-fold."""
        if not self._arrivals:
            return 0.0
        span = min(self.window, now - self._t0)
        if span <= bin_s:
            # under one full bin of history: single-bin mean, no sigma yet
            # (span clamped: sub-second history cannot resolve a rate)
            arr = [a for a in self._arrivals if a >= now - max(span, 0.0)]
            return len(arr) / max(span, 1.0)
        nbins = int(span / bin_s)            # whole bins of real history
        cutoff = now - nbins * bin_s
        arr = [a for a in self._arrivals if a >= cutoff]
        counts = np.zeros(nbins)
        for a in arr:
            b = min(int((a - cutoff) / bin_s), nbins - 1)
            counts[b] += 1
        per_s = counts / bin_s
        return float(per_s.mean() + self.k_sigma * per_s.std())
