"""Request bookkeeping: task types, SLOs, lifecycle, latency budgets (§5.1)."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class TaskType(enum.Enum):
    ONLINE = "online"
    OFFLINE = "offline"


class RequestState(enum.Enum):
    WAITING = "waiting"        # queued / pooled, no KV resident
    RUNNING = "running"        # in the active batch (prefilling or decoding)
    PREEMPTED = "preempted"    # evicted mid-flight; will be re-admitted
    FINISHED = "finished"
    ABORTED = "aborted"        # cancelled mid-flight; resources released


@dataclass(frozen=True)
class SLO:
    ttft: float = 1.0          # s, time-to-first-token
    tpot: float = 0.18         # s, time-per-output-token


_counter = itertools.count()


@dataclass
class Request:
    prompt: Tuple[int, ...]
    max_new_tokens: int
    task_type: TaskType
    arrival_time: float = 0.0
    slo: Optional[SLO] = None
    rid: int = field(default_factory=lambda: next(_counter))

    state: RequestState = RequestState.WAITING
    computed_tokens: int = 0               # positions with KV resident
    prefill_target_len: int = 0            # snapshot of known tokens at admission
    output_tokens: List[int] = field(default_factory=list)
    block_ids: List[int] = field(default_factory=list)
    n_preemptions: int = 0
    recomputed_tokens: int = 0             # prefill tokens re-done after preemption
    swapped_in_tokens: int = 0             # prefill tokens restored from host KV
    owner_pins: List[int] = field(default_factory=list)
    # block hashes carrying this request's unfinished-owner pin (set when a
    # preemption releases its committed blocks; cleared on return or abort)

    # metrics
    first_token_time: Optional[float] = None
    first_scheduled_time: Optional[float] = None   # first batch admission
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    # ------------------------------------------------------------- helpers
    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def full_tokens(self) -> Tuple[int, ...]:
        """Known token content (prompt + generated). After a recompute-mode
        preemption the generated tokens are re-prefilled as prompt (vLLM)."""
        return self.prompt + tuple(self.output_tokens)

    def admit(self, now: Optional[float] = None) -> None:
        """(Re-)admission: prefill covers all currently-known tokens.
        The first admission is stamped for queue-delay metrics."""
        self.prefill_target_len = len(self.full_tokens)
        self.state = RequestState.RUNNING
        if now is not None and self.first_scheduled_time is None:
            self.first_scheduled_time = now

    @property
    def prefill_done(self) -> bool:
        return self.computed_tokens >= self.prefill_target_len

    @property
    def remaining_prefill(self) -> int:
        return max(self.prefill_target_len - self.computed_tokens, 0)

    @property
    def n_output(self) -> int:
        return len(self.output_tokens)

    @property
    def done(self) -> bool:
        return self.n_output >= self.max_new_tokens

    @property
    def total_len(self) -> int:
        """Positions with KV resident."""
        return self.computed_tokens

    @property
    def is_online(self) -> bool:
        return self.task_type == TaskType.ONLINE

    def latency_budget(self, now: float) -> float:
        """§5.1: deadline slack for the *next* token of this request.

        Token i (0-based output index) must arrive by
        arrival + TTFT + i * TPOT. Returns remaining seconds (can be <0).
        """
        if self.slo is None:
            return float("inf")
        i = self.n_output
        deadline = self.arrival_time + self.slo.ttft + i * self.slo.tpot
        return deadline - now

    def record_token(self, tok: int, now: float) -> None:
        if self.first_token_time is None:
            self.first_token_time = now
        self.output_tokens.append(tok)
        self.token_times.append(now)
        if self.done:
            self.finish_time = now
            self.state = RequestState.FINISHED

    # metric accessors ----------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.n_output < 2:
            return None
        return (self.token_times[-1] - self.token_times[0]) / (self.n_output - 1)

    def queue_delay(self) -> Optional[float]:
        """Arrival to first batch admission (None if never scheduled)."""
        if self.first_scheduled_time is None:
            return None
        return self.first_scheduled_time - self.arrival_time
