"""Multi-tenant cluster workload: several bursty online streams with
distinct SLOs plus a shared-prefix offline corpus per tenant.

Each tenant gets its own BurstyTrace (independent tidal phase/burst seed),
its own SLO class (e.g. an interactive chat tenant vs. a relaxed API
tenant), and a LooGLE-like offline corpus whose documents are private to
the tenant — so prefix sharing exists *within* a tenant but not across
tenants. Offline submissions are interleaved across tenants (batch-API
mixing), which is exactly what scatters document groups under round-robin
dispatch and what a prefix-affinity router must undo.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import SLO, Request
from repro.data.trace import BurstyTrace
from repro.data.workload import make_offline_corpus, make_online_requests


@dataclass(frozen=True)
class TenantSpec:
    name: str
    online_rate: float = 1.0            # arrivals / s at the tidal mean
    slo: SLO = SLO(1.0, 0.1)
    prompt_mean: int = 96
    max_new_mean: int = 24
    burst_rate: float = 4.0
    burst_prob: float = 0.02
    burst_len: float = 10.0
    n_docs: int = 4                     # offline corpus: docs private to tenant
    questions_per_doc: int = 24
    doc_len: int = 256
    question_len: int = 24
    offline_new: int = 8


def default_tenants(n: int = 3) -> Tuple[TenantSpec, ...]:
    """An interactive chat tenant (tight SLO), an assistant tenant, and a
    relaxed API tenant — cycled if more are requested."""
    archetypes = (
        TenantSpec("chat", online_rate=1.5, slo=SLO(0.8, 0.08),
                   prompt_mean=96, max_new_mean=24),
        TenantSpec("assist", online_rate=1.0, slo=SLO(1.2, 0.12),
                   prompt_mean=160, max_new_mean=32),
        TenantSpec("api", online_rate=0.6, slo=SLO(2.0, 0.2),
                   prompt_mean=64, max_new_mean=16),
    )
    out = []
    for i in range(n):
        base = archetypes[i % len(archetypes)]
        name = base.name if i < len(archetypes) else f"{base.name}{i}"
        out.append(dataclasses.replace(base, name=name))
    return tuple(out)


def make_multi_tenant_workload(
        tenants: Sequence[TenantSpec], duration: float, *,
        vocab: int = 256, seed: int = 0,
        tidal_period: Optional[float] = None,
        ) -> Tuple[List[Request], List[Request]]:
    """Returns (online, offline): online merged across tenants sorted by
    arrival, offline interleaved across tenants with epsilon-increasing
    arrival times (FCFS order == mixed submission order)."""
    online: List[Request] = []
    offline: List[Request] = []
    for i, t in enumerate(tenants):
        s = seed + 101 * i
        trace = BurstyTrace(base_rate=t.online_rate,
                            tidal_period=tidal_period or 2 * duration,
                            burst_rate=t.burst_rate, burst_prob=t.burst_prob,
                            burst_len=t.burst_len, seed=s + 1)
        arrivals = trace.sample(0.0, duration)
        online.extend(make_online_requests(
            arrivals, prompt_mean=t.prompt_mean,
            prompt_std=max(t.prompt_mean // 4, 1),
            max_new_mean=t.max_new_mean, vocab=vocab, slo=t.slo, seed=s + 2))
        offline.extend(make_offline_corpus(
            t.n_docs, t.questions_per_doc, doc_len=t.doc_len,
            question_len=t.question_len, max_new=t.offline_new, vocab=vocab,
            arrival_time=0.0, shuffle=True, seed=s + 3))
    online.sort(key=lambda r: (r.arrival_time, r.rid))
    rng = np.random.default_rng(seed + 7)
    rng.shuffle(offline)
    for i, r in enumerate(offline):
        r.arrival_time = i * 1e-6
    return online, offline
