from repro.data.multi_tenant import (TenantSpec, default_tenants,
                                     make_multi_tenant_workload)
from repro.data.trace import BurstyTrace
from repro.data.workload import make_offline_corpus, make_online_requests

__all__ = ["BurstyTrace", "TenantSpec", "default_tenants",
           "make_multi_tenant_workload", "make_offline_corpus",
           "make_online_requests"]
