from repro.data.trace import BurstyTrace
from repro.data.workload import make_offline_corpus, make_online_requests

__all__ = ["BurstyTrace", "make_offline_corpus", "make_online_requests"]
