"""Synthetic workloads mirroring the paper's datasets (§7.1, Table 1).

Online  (ShareGPT-like): short prompts (~hundreds of tokens), <5% sharing.
Offline (LooGLE-like):  long document contexts shared by several questions
                        per document (>85% prefix sharing), submitted all at
                        once in a batch.
Token ids are drawn from a small vocab; content only matters for block
hashing and model execution, not semantics.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import SLO, Request, TaskType


def _tokens(rng, n: int, vocab: int) -> Tuple[int, ...]:
    return tuple(int(x) for x in rng.integers(0, vocab, n))


def make_online_requests(arrivals: Sequence[float], *,
                         prompt_mean: int = 64, prompt_std: int = 32,
                         max_new_mean: int = 32, vocab: int = 256,
                         slo: Optional[SLO] = None,
                         seed: int = 1) -> List[Request]:
    rng = np.random.default_rng(seed)
    slo = slo or SLO()
    out = []
    for t in arrivals:
        plen = max(int(rng.normal(prompt_mean, prompt_std)), 8)
        mnt = max(int(rng.exponential(max_new_mean)), 4)
        out.append(Request(prompt=_tokens(rng, plen, vocab),
                           max_new_tokens=mnt, task_type=TaskType.ONLINE,
                           arrival_time=float(t), slo=slo))
    return out


def make_offline_corpus(n_docs: int = 8, questions_per_doc: int = 8, *,
                        doc_len: int = 256, question_len: int = 24,
                        max_new: int = 16, vocab: int = 256,
                        arrival_time: float = 0.0, shuffle: bool = True,
                        seed: int = 2) -> List[Request]:
    """LooGLE-style: each document is a shared prefix for its questions.
    Prefix sharing rate ~= doc_len / (doc_len + question_len).

    By default the submission order is shuffled (batch-API submissions
    interleave users/documents) — FCFS baselines therefore lose prefix
    locality, which is exactly what Echo's KV-aware reordering restores.
    """
    rng = np.random.default_rng(seed)
    out = []
    for d in range(n_docs):
        doc = _tokens(rng, doc_len, vocab)
        for q in range(questions_per_doc):
            question = _tokens(rng, question_len, vocab)
            out.append(Request(prompt=doc + question, max_new_tokens=max_new,
                               task_type=TaskType.OFFLINE,
                               arrival_time=arrival_time))
    if shuffle:
        rng.shuffle(out)
    # FCFS order == submission order: epsilon-increasing arrival times
    for i, r in enumerate(out):
        r.arrival_time = arrival_time + i * 1e-6
    return out


def sharing_rate(reqs: Sequence[Request], block_size: int = 16) -> float:
    """Fraction of prompt blocks shared with at least one other request
    (Table 1's 'Shared Rate' metric, block-granular)."""
    from collections import Counter
    from repro.core.block_manager import chain_hash
    counts: Counter = Counter()
    total = 0
    chains = []
    for r in reqs:
        prev = 0
        chain = []
        for i in range(len(r.prompt) // block_size):
            prev = chain_hash(prev, tuple(r.prompt[i * block_size:(i + 1) * block_size]))
            chain.append(prev)
            counts[prev] += 1
        chains.append(chain)
        total += len(chain)
    if total == 0:
        return 0.0
    shared = sum(1 for chain in chains for h in chain if counts[h] > 1)
    return shared / total
