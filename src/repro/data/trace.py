"""Online arrival trace generator: tidal (diurnal) + bursty (Fig. 2).

Arrivals follow a non-homogeneous Poisson process whose rate is
    lambda(t) = base * tidal(t) * burst(t)
with a sinusoidal tidal factor (configurable peak/off-peak ratio, the paper
observes ~6x) and a two-state Markov burst multiplier (flash crowds).
Timestamps can be scaled to match experimental capacity, as the paper does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class BurstyTrace:
    base_rate: float = 2.0          # arrivals / s at the tidal mean
    tidal_period: float = 86_400.0  # s (24 h)
    tidal_ratio: float = 6.0        # peak / off-peak rate ratio
    burst_rate: float = 4.0         # multiplier while bursting
    burst_prob: float = 0.02        # P(enter burst) per second
    burst_len: float = 20.0         # mean burst duration (s)
    seed: int = 0

    def rate(self, t: float, bursting: bool = False) -> float:
        r = self.tidal_ratio
        tidal = (1 + (r - 1) / (r + 1) *
                 np.sin(2 * np.pi * t / self.tidal_period - np.pi / 2))
        lam = self.base_rate * tidal
        return lam * (self.burst_rate if bursting else 1.0)

    def sample(self, t0: float, t1: float) -> List[float]:
        """Arrival timestamps in [t0, t1) via thinning."""
        rng = np.random.default_rng(self.seed)
        lam_max = self.base_rate * 2.0 * self.burst_rate
        out = []
        t = t0
        bursting = False
        next_state_change = t0
        while t < t1:
            if t >= next_state_change:
                if bursting:
                    bursting = False
                    next_state_change = t + rng.exponential(1.0 / max(self.burst_prob, 1e-9))
                else:
                    bursting = True
                    next_state_change = t + rng.exponential(self.burst_len)
                # first toggle at t0 starts calm
                if t == t0:
                    bursting = False
            t += rng.exponential(1.0 / lam_max)
            if t >= t1:
                break
            if rng.random() < self.rate(t, bursting) / lam_max:
                out.append(t)
        return out
