"""Request-lifecycle and per-iteration tracing with Chrome-trace export.

``Tracer`` is a bounded ring buffer of trace events exported as Chrome
Trace Event JSON (the ``traceEvents`` array format) — load the file at
https://ui.perfetto.dev or chrome://tracing. The timeline is the engine's
clock (virtual seconds on the simulator paths, wall seconds otherwise)
mapped to microseconds.

Track layout (one Perfetto "process" per replica):

  pid 0..N-1   replica engines
    tid 1      schedule       — scheduler wall time per iteration
    tid 2      kernel         — the compute leg of each iteration
    tid 3      swap copy-stream — PCIe transfer spans + swap-out instants
    tid 16+rid one track per request: queued span, prefill chunk spans,
               decode spans, preempt/swap-in instants, parked spans
  pid 9997     rt frontdoor   — per-connection wall-clock spans (submit to
               terminal, first-token instant); NOTE this pid's timeline is
               the *serving* clock, the engine pids' is the backend clock
  pid 9998     service        — admission shed/abort instants
  pid 9999     router         — cluster dispatch/steal instants

Bounded overhead: events are stored as tuples in a ``deque(maxlen=cap)``
(oldest events drop first; ``dropped_events`` counts them) and the JSON
dicts are only built at export time. Zero cost when not attached — the
engine skips detail construction entirely when no listener overrides
``on_iteration``.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.engine import EngineListener, IterationDetail, IterationRecord
from repro.core.request import Request, RequestState

TID_SCHEDULE = 1
TID_KERNEL = 2
TID_SWAP = 3
TID_REQ_BASE = 16          # request track = TID_REQ_BASE + rid
RT_PID = 9997
SERVICE_PID = 9998
ROUTER_PID = 9999


class Tracer:
    """Ring-buffered span/instant store with Chrome-trace JSON export."""

    def __init__(self, cap: int = 200_000):
        self.cap = cap
        self._events: deque = deque(maxlen=cap)
        self._procs: Dict[int, str] = {}
        self._threads: Dict[Tuple[int, int], str] = {}
        self.n_recorded = 0
        self._engine_tracers: List[_EngineTracer] = []

    # ------------------------------------------------------------- recording
    def span(self, pid: int, tid: int, name: str, t0: float, dur: float,
             args: Optional[dict] = None, cat: str = "echo") -> None:
        self.n_recorded += 1
        self._events.append(("X", name, t0, max(dur, 0.0), pid, tid, args,
                             cat))

    def instant(self, pid: int, tid: int, name: str, t: float,
                args: Optional[dict] = None, cat: str = "echo") -> None:
        self.n_recorded += 1
        self._events.append(("i", name, t, 0.0, pid, tid, args, cat))

    def set_process(self, pid: int, name: str) -> None:
        self._procs.setdefault(pid, name)

    def set_thread(self, pid: int, tid: int, name: str) -> None:
        self._threads.setdefault((pid, tid), name)

    @property
    def dropped_events(self) -> int:
        return self.n_recorded - len(self._events)

    # ------------------------------------------------------------- wiring
    def attach(self, target) -> "Tracer":
        """Attach to an ``EchoService``, a serving backend, or a bare
        ``EchoEngine``: one lifecycle listener per engine (pid = replica
        index), plus router dispatch/steal hooks and admission instants
        when the target exposes them."""
        service = target if hasattr(target, "backend") else None
        backend = service.backend if service is not None else target
        engines = backend.engines() if hasattr(backend, "engines") \
            else [backend]
        for i, eng in enumerate(engines):
            self.attach_engine(eng, pid=i)
        sim = getattr(backend, "sim", None)
        if sim is not None and getattr(sim, "router", None) is not None:
            self._attach_router(sim.router)
        if sim is not None and hasattr(sim, "on_lifecycle"):
            self._attach_lifecycle(sim)
        if service is not None:
            self._attach_service(service)
        return self

    def attach_engine(self, engine, pid: int = 0) -> "_EngineTracer":
        self.set_process(pid, f"replica {pid}")
        self.set_thread(pid, TID_SCHEDULE, "schedule")
        self.set_thread(pid, TID_KERNEL, "kernel")
        self.set_thread(pid, TID_SWAP, "swap copy-stream")
        lt = _EngineTracer(self, pid)
        engine.listeners.append(lt)
        self._engine_tracers.append(lt)
        return lt

    def _attach_router(self, router) -> None:
        self.set_process(ROUTER_PID, "router")
        self.set_thread(ROUTER_PID, 1, "dispatch")
        self.set_thread(ROUTER_PID, 2, "steal")
        if router.on_dispatch is None:
            router.on_dispatch = lambda req, rep_id, t: self.instant(
                ROUTER_PID, 1, f"dispatch r{rep_id}", t,
                {"rid": req.rid, "task": req.task_type.value,
                 "replica": rep_id})
        if router.on_steal is None:
            router.on_steal = lambda req, frm, to, t: self.instant(
                ROUTER_PID, 2, f"steal r{frm}->r{to}", t,
                {"rid": req.rid, "from": frm, "to": to})

    def _attach_lifecycle(self, sim) -> None:
        """Fleet-membership timeline: one instant per replica lifecycle
        transition (JOINING/UP/DEGRADED/DRAINING/DOWN) on the router
        process, plus retroactive instants for transitions that already
        happened. Chains an existing ``on_lifecycle`` tap."""
        self.set_process(ROUTER_PID, "router")
        self.set_thread(ROUTER_PID, 3, "lifecycle")
        for t, rid, state in getattr(sim, "lifecycle_log", []):
            self.instant(ROUTER_PID, 3, f"r{rid} {state}", t,
                         {"replica": rid, "state": state})
        prev = sim.on_lifecycle

        def _tap(rid: int, state: str, t: float, _prev=prev) -> None:
            self.instant(ROUTER_PID, 3, f"r{rid} {state}", t,
                         {"replica": rid, "state": state})
            if _prev is not None:
                _prev(rid, state, t)

        sim.on_lifecycle = _tap

    def _attach_service(self, service) -> None:
        self.set_process(SERVICE_PID, "service")
        self.set_thread(SERVICE_PID, 1, "admission")
        bus = service.events

        def _shed(handle):
            self.instant(SERVICE_PID, 1, "shed", service.backend.now(),
                         {"rid": handle.rid})

        def _abort(handle):
            self.instant(SERVICE_PID, 1, "abort", service.backend.now(),
                         {"rid": handle.rid})

        bus.subscribe("shed", _shed)
        bus.subscribe("abort", _abort)

    # ------------------------------------------------------------- queries
    def preempted_rids(self) -> set:
        return set().union(*(lt.preempted for lt in self._engine_tracers)) \
            if self._engine_tracers else set()

    def swapped_rids(self) -> set:
        return set().union(*(lt.swapped for lt in self._engine_tracers)) \
            if self._engine_tracers else set()

    # ------------------------------------------------------------- export
    def to_dict(self) -> dict:
        events: List[dict] = []
        for pid, name in sorted(self._procs.items()):
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": name}})
        for (pid, tid), name in sorted(self._threads.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})
            events.append({"ph": "M", "name": "thread_sort_index",
                           "pid": pid, "tid": tid,
                           "args": {"sort_index": tid}})
        for ph, name, t, dur, pid, tid, args, cat in self._events:
            ev = {"ph": ph, "name": name, "ts": t * 1e6, "pid": pid,
                  "tid": tid, "cat": cat}
            if ph == "X":
                ev["dur"] = dur * 1e6
            elif ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"recorded": self.n_recorded,
                              "dropped": self.dropped_events}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


class _EngineTracer(EngineListener):
    """Per-engine lifecycle listener feeding one replica's tracks.

    Request phases are tracked as a tiny state machine (rid -> (phase, t0))
    so each request costs O(transitions) events, not O(tokens): a queued
    span from arrival to admission, per-iteration prefill chunk spans, one
    decode span per contiguous decode residency, and parked spans between
    preemption and re-admission."""

    def __init__(self, tracer: Tracer, pid: int):
        self.tr = tracer
        self.pid = pid
        self._phase: Dict[int, Tuple[str, float]] = {}
        self._named: set = set()
        self.preempted: set = set()
        self.swapped: set = set()

    # ------------------------------------------------------------- helpers
    def _req_tid(self, req: Request) -> int:
        tid = TID_REQ_BASE + req.rid
        if req.rid not in self._named:
            self._named.add(req.rid)
            self.tr.set_thread(self.pid, tid,
                               f"req {req.rid} ({req.task_type.value})")
        return tid

    def _close_phase(self, req: Request, t: float) -> None:
        entry = self._phase.pop(req.rid, None)
        if entry is None:
            return
        phase, t0 = entry
        if t > t0:
            self.tr.span(self.pid, self._req_tid(req), phase, t0, t - t0)

    # ------------------------------------------------------------- hooks
    def on_iteration(self, rec: IterationRecord,
                     detail: IterationDetail) -> None:
        tr, pid = self.tr, self.pid
        t0, t1 = detail.t_start, detail.t_end
        if detail.schedule_wall > 0:
            tr.span(pid, TID_SCHEDULE, "schedule", t0, detail.schedule_wall,
                    {"n_prefill": rec.n_prefill, "n_decode": rec.n_decode})
        rel = (detail.predicted_time - rec.iter_time) \
            / max(rec.iter_time, 1e-12)
        tr.span(pid, TID_KERNEL, "exec", t0, detail.compute_time,
                {"iter_time": rec.iter_time,
                 "predicted": detail.predicted_time,
                 "rel_err": rel,
                 "online_tokens": rec.online_tokens,
                 "offline_tokens": rec.offline_tokens})
        if rec.swap_transfer_time > 0:
            tr.span(pid, TID_SWAP, "swap copy", t0, rec.swap_transfer_time,
                    {"exposed": rec.swap_exposed_time,
                     "in_tokens": rec.swap_in_tokens,
                     "out_tokens": rec.swap_out_tokens})
        for req in detail.admitted:
            entry = self._phase.get(req.rid)
            if entry is None:          # fresh: queued since arrival
                if t0 > req.arrival_time:
                    self.tr.span(pid, self._req_tid(req), "queued",
                                 req.arrival_time, t0 - req.arrival_time)
            else:                      # parked (or re-queued): close it
                self._close_phase(req, t0)
        for req, start, end in detail.prefill_spans:
            tr.span(pid, self._req_tid(req), f"prefill [{start}:{end}]",
                    t0, t1 - t0, {"chunk": end - start})
        for req in detail.decodes:
            if req.state in (RequestState.FINISHED, RequestState.ABORTED):
                continue               # on_finish already closed the span
            if self._phase.get(req.rid, ("", 0.0))[0] != "decode":
                self._phase[req.rid] = ("decode", t0)

    def on_preempt(self, req: Request, t: float) -> None:
        self._close_phase(req, t)
        self.preempted.add(req.rid)
        self.tr.instant(self.pid, self._req_tid(req), "preempt", t,
                        {"n_preemptions": req.n_preemptions})
        self._phase[req.rid] = ("parked", t)

    def on_finish(self, req: Request, t: float) -> None:
        self._close_phase(req, t)
        self.tr.instant(self.pid, self._req_tid(req), "finish", t,
                        {"n_output": req.n_output,
                         "ttft": req.ttft(), "tpot": req.tpot()})

    def on_swap_in(self, req: Request, n_tokens: int, t: float) -> None:
        self.swapped.add(req.rid)
        self.tr.instant(self.pid, self._req_tid(req), "swap-in", t,
                        {"tokens": n_tokens})

    def on_swap_out(self, n_tokens: int, t: float) -> None:
        self.tr.instant(self.pid, TID_SWAP, "swap-out", t,
                        {"tokens": n_tokens})
