"""Metric probes: the EventBus-to-registry bridge and the estimator-drift
probes fed by the engine's per-iteration hook.

``ServiceMetrics`` subscribes the serving bus and mirrors the lifecycle
stream into labeled counters and latency histograms. ``EngineProbe`` is an
``EngineListener`` that records per-iteration timings, predicted-vs-clock
residuals (scheduler plan estimate and — via the calibrator's
``on_residual`` tap — the pre-refit Eq.6-8 residual per sample),
MemoryPredictor-vs-actual online-KV occupancy, and block-pool fill.

Import discipline: this module must NOT import ``repro.serving`` at module
level — ``repro.serving.events`` itself imports ``repro.obs.metrics``, which
executes the ``repro.obs`` package init. The bus is duck-typed
(``subscribe(event, cb)``) instead.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.engine import (EchoEngine, EngineListener, IterationDetail,
                               IterationRecord)
from repro.obs.metrics import (BYTES_BUCKETS, FRACTION_BUCKETS, ITER_BUCKETS,
                               LATENCY_BUCKETS, REL_ERR_BUCKETS,
                               MetricsRegistry)


class ServiceMetrics:
    """Bus-level lifecycle metrics. All label children are resolved once at
    construction; the per-event handlers touch only cached handles."""

    def __init__(self, bus, registry: MetricsRegistry):
        self.registry = registry
        r = registry
        tokens = r.counter("tokens_total", "generated tokens", ("task",))
        self._tok_online = tokens.labels("online")
        self._tok_offline = tokens.labels("offline")
        finished = r.counter("requests_finished_total", "finished requests",
                             ("task",))
        self._fin_online = finished.labels("online")
        self._fin_offline = finished.labels("offline")
        events = r.counter("lifecycle_events_total",
                           "preempt/abort/shed/requeue events", ("kind",))
        self._preempt = events.labels("preempt")
        self._abort = events.labels("abort")
        self._shed = events.labels("shed")
        self._requeue = events.labels("requeue")
        swap_tok = r.counter("swap_tokens_total",
                             "KV tokens moved across the host tier",
                             ("direction",))
        self._swap_in = swap_tok.labels("in")
        self._swap_out = swap_tok.labels("out")
        swap_s = r.counter("swap_seconds_total",
                           "PCIe copy-stream seconds (transfer) and the "
                           "tail not hidden under compute (exposed)",
                           ("kind",))
        self._transfer_s = swap_s.labels("transfer")
        self._exposed_s = swap_s.labels("exposed")
        self.ttft = r.histogram("ttft_seconds", "time to first token",
                                buckets=LATENCY_BUCKETS)
        self.tpot = r.histogram("tpot_seconds", "time per output token",
                                buckets=LATENCY_BUCKETS)
        self.queue_delay = r.histogram(
            "queue_delay_seconds", "arrival to first batch admission",
            buckets=LATENCY_BUCKETS)
        bus.subscribe("token", self._on_token)
        bus.subscribe("finish", self._on_finish)
        bus.subscribe("preempt", lambda h: self._preempt.inc())
        bus.subscribe("abort", lambda h: self._abort.inc())
        bus.subscribe("shed", lambda h: self._shed.inc())
        bus.subscribe("requeue", lambda h: self._requeue.inc())
        bus.subscribe("swap_in", self._on_swap_in)
        bus.subscribe("swap_out", self._on_swap_out)
        bus.subscribe("swap_overlap", self._on_swap_overlap)

    # ------------------------------------------------------------- handlers
    def _on_token(self, ev) -> None:
        if ev.handle.request.is_online:
            self._tok_online.inc()
        else:
            self._tok_offline.inc()

    def _on_finish(self, handle) -> None:
        req = handle.request
        qd = req.queue_delay()
        if qd is not None:
            self.queue_delay.observe(qd)
        if req.is_online:
            self._fin_online.inc()
            ttft, tpot = req.ttft(), req.tpot()
            if ttft is not None:
                self.ttft.observe(ttft)
            if tpot is not None:
                self.tpot.observe(tpot)
        else:
            self._fin_offline.inc()

    def _on_swap_in(self, ev) -> None:
        self._swap_in.inc(ev.tokens)

    def _on_swap_out(self, ev) -> None:
        self._swap_out.inc(ev.tokens)

    def _on_swap_overlap(self, ev) -> None:
        self._transfer_s.inc(ev.transfer)
        self._exposed_s.inc(ev.exposed)


class EngineProbe(EngineListener):
    """Per-engine drift probes (one instance per replica, ``replica`` label).

    Everything is recorded from ``on_iteration`` so the plain serving path
    (no probe attached) never builds an ``IterationDetail``. The calibrator
    residual tap is chained, not replaced — an already-installed callback
    keeps firing."""

    def __init__(self, engine: EchoEngine, registry: MetricsRegistry, *,
                 replica: int = 0):
        self.engine = engine
        rep = str(replica)
        r = registry
        self._iter = r.histogram(
            "iteration_seconds", "engine iteration time", ("replica",),
            buckets=ITER_BUCKETS).labels(rep)
        self._sched = r.histogram(
            "schedule_seconds", "scheduler wall time per iteration",
            ("replica",), buckets=ITER_BUCKETS).labels(rep)
        self._plan_err = r.histogram(
            "plan_rel_err", "relative error of the plan's scored estimate "
            "vs the observed iteration time", ("replica",),
            buckets=REL_ERR_BUCKETS).labels(rep)
        self._plan_bias = r.gauge(
            "plan_bias", "signed (predicted-observed)/observed of the last "
            "iteration", ("replica",)).labels(rep)
        est_err = r.histogram(
            "estimator_rel_err", "pre-refit Eq.6-8 relative error per "
            "calibrator sample", ("replica", "kind"), buckets=REL_ERR_BUCKETS)
        self._cal_iter = est_err.labels(rep, "iter")
        self._cal_swap = est_err.labels(rep, "swap")
        self._cal_migrate = est_err.labels(rep, "migrate")
        ewma = r.gauge("calibrator_ewma_rel_err",
                       "calibrator EWMA relative error", ("replica", "kind"))
        self._ewma_iter = ewma.labels(rep, "iter")
        self._ewma_swap = ewma.labels(rep, "swap")
        self._ewma_migrate = ewma.labels(rep, "migrate")
        refits = r.gauge("calibrator_refits",
                         "cumulative calibrator refits", ("replica", "kind"))
        self._refits_iter = refits.labels(rep, "iter")
        self._refits_swap = refits.labels(rep, "swap")
        self._refits_migrate = refits.labels(rep, "migrate")
        self._mem_pred = r.gauge(
            "predicted_online_kv_tokens", "MemoryPredictor mu+k*sigma online "
            "KV demand", ("replica",)).labels(rep)
        self._mem_actual = r.gauge(
            "online_kv_tokens", "online KV tokens resident",
            ("replica",)).labels(rep)
        self._mem_err = r.histogram(
            "mem_pred_rel_err", "|predicted-actual|/actual online KV "
            "occupancy", ("replica",), buckets=REL_ERR_BUCKETS).labels(rep)
        self._kv = {
            k: r.gauge("kv_blocks", "block-pool occupancy by state",
                       ("replica", "state")).labels(rep, k)
            for k in ("free", "running", "cached", "threshold",
                      "host_used", "host_capacity")}
        # family-labeled link traffic: the same iteration record reads as
        # per-token KV pages on a paged engine and as fixed-size snapshots
        # on a state-family one — the byte histograms keep them comparable
        fam = engine.bm.io.family
        swap_bytes = r.histogram(
            "swap_bytes", "per-iteration PCIe payload over the host tier",
            ("replica", "family", "direction"), buckets=BYTES_BUCKETS)
        self._swap_in_bytes = swap_bytes.labels(rep, fam, "in")
        self._swap_out_bytes = swap_bytes.labels(rep, fam, "out")
        self._swap_bytes_total = r.counter(
            "swap_bytes_total", "cumulative PCIe bytes over the host tier",
            ("replica", "family", "direction"))
        self._swap_in_bytes_c = self._swap_bytes_total.labels(rep, fam, "in")
        self._swap_out_bytes_c = self._swap_bytes_total.labels(rep, fam,
                                                               "out")
        # cross-replica KV migration: fabric payload this replica imported
        # (blocks shipped from a drained / stolen-from peer's tiers)
        self._migrate_in_bytes = r.histogram(
            "migrate_bytes", "per-iteration inter-replica KV migration "
            "payload landed in the host tier", ("replica", "family"),
            buckets=BYTES_BUCKETS).labels(rep, fam)
        self._migrate_in_bytes_c = r.counter(
            "migrate_bytes_total", "cumulative inter-replica KV migration "
            "bytes imported", ("replica", "family")).labels(rep, fam)
        self._swap_exposed = r.histogram(
            "swap_exposed_seconds", "per-iteration swap tail not hidden "
            "under compute", ("replica",), buckets=ITER_BUCKETS).labels(rep)
        self._swap_hidden = r.histogram(
            "swap_hidden_frac", "per-iteration fraction of swap traffic "
            "hidden under compute", ("replica",),
            buckets=FRACTION_BUCKETS).labels(rep)
        cal = engine.calibrator
        if cal is not None:
            prev = cal.on_residual

            def _tap(kind: str, rel: float, _prev=prev) -> None:
                h = {"iter": self._cal_iter, "swap": self._cal_swap,
                     "migrate": self._cal_migrate}.get(kind)
                if h is not None:
                    h.observe(rel)
                if _prev is not None:
                    _prev(kind, rel)

            cal.on_residual = _tap

    # ------------------------------------------------------------- hook
    def on_iteration(self, rec: IterationRecord,
                     detail: IterationDetail) -> None:
        self._iter.observe(rec.iter_time)
        if detail.schedule_wall > 0:
            self._sched.observe(detail.schedule_wall)
        if rec.iter_time > 0:
            err = (detail.predicted_time - rec.iter_time) / rec.iter_time
            self._plan_err.observe(abs(err))
            self._plan_bias.set(err)
        predicted = self.engine.mem_pred.predict()
        actual = self.engine._online_kv_tokens()
        self._mem_pred.set(predicted)
        self._mem_actual.set(actual)
        if actual > 0:
            self._mem_err.observe(abs(predicted - actual) / actual)
        snap = self.engine.bm.occupancy_snapshot()
        for k, g in self._kv.items():
            g.set(snap[k])
        cal = self.engine.calibrator
        if cal is not None:
            if cal.ewma_err is not None:
                self._ewma_iter.set(cal.ewma_err)
            if cal.ewma_swap_err is not None:
                self._ewma_swap.set(cal.ewma_swap_err)
            if cal.ewma_migrate_err is not None:
                self._ewma_migrate.set(cal.ewma_migrate_err)
            self._refits_iter.set(cal.refits)
            self._refits_swap.set(cal.swap_refits)
            self._refits_migrate.set(cal.migrate_refits)
        if rec.migrate_in_bytes > 0:
            self._migrate_in_bytes.observe(rec.migrate_in_bytes)
            self._migrate_in_bytes_c.inc(rec.migrate_in_bytes)
        if rec.swap_in_bytes > 0:
            self._swap_in_bytes.observe(rec.swap_in_bytes)
            self._swap_in_bytes_c.inc(rec.swap_in_bytes)
        if rec.swap_out_bytes > 0:
            self._swap_out_bytes.observe(rec.swap_out_bytes)
            self._swap_out_bytes_c.inc(rec.swap_out_bytes)
        if rec.swap_transfer_time > 0:
            self._swap_exposed.observe(rec.swap_exposed_time)
            self._swap_hidden.observe(
                max(1.0 - rec.swap_exposed_time / rec.swap_transfer_time,
                    0.0))


class RTProbe:
    """Wall-clock serving metrics for the real-time front door.

    Everything the engine-side probes record lives in the backend's clock
    domain; this probe records what a *client* experiences — wall seconds
    from submit to first token (``rt_ttft_wall_seconds``) and per token
    after it — via ``AsyncEchoEngine.on_request_done``, which fires on the
    event-loop thread at every handle's terminal transition. With a tracer
    it draws one span per connection at ``RT_PID`` (serving-clock
    timeline): submit-to-terminal, first-token instant inside it.

    Duck-typed against the engine (``on_request_done``/``stats``/
    ``live_requests``) for the same import-discipline reason as the bus:
    ``repro.rt`` imports ``repro.serving`` which imports this package.
    """

    def __init__(self, rt, registry: MetricsRegistry, tracer=None):
        self.rt = rt
        self.tracer = tracer
        r = registry
        self.ttft_wall = r.histogram(
            "rt_ttft_wall_seconds", "serving-clock time to first token",
            buckets=LATENCY_BUCKETS)
        self.tpot_wall = r.histogram(
            "rt_tpot_wall_seconds", "serving-clock time per output token",
            buckets=LATENCY_BUCKETS)
        self.latency_wall = r.histogram(
            "rt_request_wall_seconds", "serving-clock submit-to-terminal "
            "latency", buckets=LATENCY_BUCKETS)
        done = r.counter("rt_requests_total",
                         "terminal real-time requests", ("status",))
        self._done = {s: done.labels(s)
                      for s in ("finished", "aborted", "shed")}
        self._live = r.gauge("rt_live_requests",
                             "handles between submit and terminal")
        self._slow = r.gauge("rt_slow_consumer_aborts",
                             "token-queue-cap aborts so far")
        if tracer is not None:
            from repro.obs.trace import RT_PID
            self._rt_pid = RT_PID
            tracer.set_process(RT_PID, "rt frontdoor")
        rt.on_request_done(self._on_done)

    def _on_done(self, handle) -> None:
        status = handle.status.value
        self._done.get(status, self._done["aborted"]).inc()
        lat = handle.wall_latency()
        if lat is not None:
            self.latency_wall.observe(lat)
        ttft, tpot = handle.wall_ttft(), handle.wall_tpot()
        if ttft is not None:
            self.ttft_wall.observe(ttft)
        if tpot is not None:
            self.tpot_wall.observe(tpot)
        self._live.set(self.rt.live_requests())
        self._slow.set(self.rt.stats.slow_consumer_aborts)
        if self.tracer is not None:
            from repro.obs.trace import TID_REQ_BASE
            tid = TID_REQ_BASE + handle.rid
            self.tracer.set_thread(self._rt_pid, tid, f"conn r{handle.rid}")
            self.tracer.span(
                self._rt_pid, tid, f"r{handle.rid} {status}",
                handle.t_submit_wall, lat or 0.0,
                args={"tokens": handle.n_tokens,
                      "ttft_wall": ttft, "tpot_wall": tpot})
            if handle.t_first_token_wall is not None:
                self.tracer.instant(self._rt_pid, tid, "first_token",
                                    handle.t_first_token_wall)


def instrument_rt(rt, registry: MetricsRegistry, tracer=None) -> RTProbe:
    """Attach the wall-clock front-door probe to an ``AsyncEchoEngine``
    (the service-level probes are attached separately by
    ``AsyncEchoEngine.instrument``)."""
    return RTProbe(rt, registry, tracer)


# ----------------------------------------------------------------- wiring
def instrument_engine(engine: EchoEngine, registry: MetricsRegistry,
                      tracer=None, *, replica: int = 0) -> EngineProbe:
    """Attach the drift probes (and optionally a tracer track) to one
    engine. Returns the probe (already registered as a listener)."""
    probe = EngineProbe(engine, registry, replica=replica)
    engine.listeners.append(probe)
    if tracer is not None:
        tracer.attach_engine(engine, pid=replica)
    return probe


def instrument(service, registry: MetricsRegistry,
               tracer=None) -> Tuple[ServiceMetrics, List[EngineProbe]]:
    """Attach the full probe set to an ``EchoService``: the bus bridge plus
    one ``EngineProbe`` per backend engine; with a tracer, the lifecycle
    tracks too (replica pids line up between metrics and trace)."""
    sm = ServiceMetrics(service.events, registry)
    backend = service.backend
    engines = backend.engines() if hasattr(backend, "engines") \
        else [backend]
    probes = [EngineProbe(eng, registry, replica=i)
              for i, eng in enumerate(engines)]
    for eng, probe in zip(engines, probes):
        eng.listeners.append(probe)
    if tracer is not None:
        tracer.attach(service)
    return sm, probes
