"""Observability layer: lifecycle tracing (Chrome-trace/Perfetto export),
a labeled metrics registry with Prometheus/JSON exposition, and
estimator-drift probes over the engine's calibration loop.

Import discipline: nothing here may import ``repro.serving`` at module
level — ``repro.serving.events`` imports ``repro.obs.metrics``, which
executes this package init. Probes take the bus duck-typed instead.
"""
from repro.obs.metrics import (Counter, FRACTION_BUCKETS, Gauge, Histogram,
                               ITER_BUCKETS, LATENCY_BUCKETS,
                               MetricsRegistry, REL_ERR_BUCKETS,
                               parse_prometheus)
from repro.obs.probes import (EngineProbe, ServiceMetrics, instrument,
                              instrument_engine)
from repro.obs.trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Tracer",
    "EngineProbe", "ServiceMetrics", "instrument", "instrument_engine",
    "parse_prometheus", "LATENCY_BUCKETS", "ITER_BUCKETS",
    "REL_ERR_BUCKETS", "FRACTION_BUCKETS",
]
