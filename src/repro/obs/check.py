"""Artifact smoke checks: is this a loadable Chrome trace / parseable
Prometheus exposition?  Used by CI after the benchmark jobs and by the
tests; importable (``check_trace`` / ``check_prometheus``) or runnable:

    python -m repro.obs.check trace.json metrics.prom
"""
from __future__ import annotations

import json
import sys
from typing import List

from repro.obs.metrics import parse_prometheus


def check_trace(path: str) -> dict:
    """Validate a Chrome-trace JSON file; returns summary counts. Raises
    ``ValueError`` on anything Perfetto would refuse to load."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise ValueError(f"{path}: no traceEvents array")
    events = data["traceEvents"]
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    n_spans = n_instants = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"{path}: event {i} is not an object")
        for key in ("ph", "name", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"{path}: event {i} missing {key!r}")
        ph = ev["ph"]
        if ph != "M" and "ts" not in ev:
            raise ValueError(f"{path}: event {i} ({ph!r}) missing ts")
        if ph == "X":
            if "dur" not in ev or ev["dur"] < 0:
                raise ValueError(f"{path}: span {i} has no valid dur")
            n_spans += 1
        elif ph == "i":
            n_instants += 1
    if n_spans == 0:
        raise ValueError(f"{path}: no complete ('X') spans recorded")
    return {"events": len(events), "spans": n_spans, "instants": n_instants}


def check_prometheus(path: str) -> dict:
    """Validate a Prometheus text file; returns summary counts."""
    with open(path) as f:
        series = parse_prometheus(f.read())
    n = sum(len(v) for v in series.values())
    return {"metrics": len(series), "samples": n}


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python -m repro.obs.check <artifact>...", file=sys.stderr)
        return 2
    for path in argv:
        try:
            # dispatch on content, not filename: traces are JSON objects
            # with a traceEvents array, anything else is exposition text
            with open(path) as f:
                head = f.read(512)
            if head.lstrip().startswith("{"):
                summary = check_trace(path)
            else:
                summary = check_prometheus(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            return 1
        print(f"ok {path}: " + ", ".join(f"{k}={v}"
                                         for k, v in summary.items()))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
