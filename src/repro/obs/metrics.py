"""Labeled counters, gauges, and fixed-bucket histograms with JSON and
Prometheus text exposition.

Design constraints (ISSUE 6): bounded overhead when enabled, zero when not.
Hot paths resolve a labeled child ONCE (``metric.labels(...)`` returns a
cached handle) and then do plain attribute arithmetic per event — no dict
construction, no label hashing, no allocation on the event path. Histograms
are pre-bucketed: ``observe`` is one ``bisect`` into a fixed bound tuple
plus two adds. Percentile queries interpolate inside the bucket, which is
exact enough for p50/p90/p99 reporting and costs O(buckets) only at query
time, never at record time.

The module is import-clean (stdlib only) so anything — serving, engine,
benchmarks — can embed a ``Histogram`` without dragging in the rest of the
observability layer.
"""
from __future__ import annotations

import json
import re
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Shared bucket families (seconds unless noted). Chosen to straddle the
# virtual-clock magnitudes of the A100/H100 presets: iteration times land in
# the 1-100 ms decades, TTFT/queue delay in 10 ms - 10 s, and relative
# errors (unitless) in 0.5% - 500%.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
ITER_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                0.25, 0.5, 1.0)
REL_ERR_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
                   2.5, 5.0)
FRACTION_BUCKETS = (0.01, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7,
                    0.8, 0.9, 0.95, 0.99, 1.0)
# Per-iteration PCIe swap payloads (bytes): state-family snapshots sit in
# the 10 KB - 1 MB decades, paged KV restores in 1 MB - 1 GB.
BYTES_BUCKETS = (1e4, 1e5, 1e6, 4e6, 1.6e7, 6.4e7, 2.56e8, 1e9)


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class _HistogramChild:
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...]):
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = overflow (+Inf)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def percentile(self, q: float) -> Optional[float]:
        """Linear interpolation inside the target bucket; the overflow
        bucket reports its lower bound (there is no upper edge to reach)."""
        if self.count == 0:
            return None
        target = q * self.count
        seen = 0.0
        lo = 0.0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                hi = self.bounds[i] if i < len(self.bounds) else lo
                frac = (target - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
            if i < len(self.bounds):
                lo = self.bounds[i]
        return self.bounds[-1]


class _Metric:
    """Shared labeled-children machinery. ``labels()`` returns the cached
    child for a label-value tuple — resolve once, hold the handle."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._children: Dict[Tuple[str, ...], object] = {}
        if not self.label_names:                 # unlabeled: one child
            self._default = self._children[()] = self._new_child()

    def _new_child(self):
        raise NotImplementedError

    def labels(self, *values, **kv):
        if kv:
            values = tuple(str(kv[k]) for k in self.label_names)
        else:
            values = tuple(str(v) for v in values)
        child = self._children.get(values)
        if child is None:
            if len(values) != len(self.label_names):
                raise ValueError(f"{self.name}: expected labels "
                                 f"{self.label_names}, got {values}")
            child = self._children[values] = self._new_child()
        return child

    # unlabeled sugar --------------------------------------------------
    def inc(self, v: float = 1.0) -> None:
        self._default.inc(v)

    def set(self, v: float) -> None:
        self._default.set(v)

    def observe(self, v: float) -> None:
        self._default.observe(v)

    def percentile(self, q: float):
        return self._default.percentile(q)


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild()


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str, labels: Sequence[str] = (), *,
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        super().__init__(name, help, labels)

    def _new_child(self):
        return _HistogramChild(self.buckets)


class MetricsRegistry:
    """Flat registry of named metrics with dual exposition.

    ``to_prometheus()`` emits the text format (``<ns>_<name>`` full names,
    histogram ``_bucket``/``_sum``/``_count`` series with cumulative
    ``le`` labels); ``to_json()`` a structured snapshot for artifacts."""

    def __init__(self, namespace: str = "echo"):
        self.namespace = namespace
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, metric: _Metric) -> _Metric:
        existing = self._metrics.get(metric.name)
        if existing is not None:
            if type(existing) is not type(metric) or \
                    existing.label_names != metric.label_names:
                raise ValueError(f"metric {metric.name!r} re-registered "
                                 "with a different shape")
            return existing
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter(name, help, labels))

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge(name, help, labels))

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), *,
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help, labels, buckets=buckets))

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def __iter__(self) -> Iterable[_Metric]:
        return iter(self._metrics.values())

    # ------------------------------------------------------------ exposition
    @staticmethod
    def _fmt_labels(names: Tuple[str, ...], values: Tuple[str, ...],
                    extra: str = "") -> str:
        parts = [f'{k}="{_escape(v)}"' for k, v in zip(names, values)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for m in self._metrics.values():
            full = f"{self.namespace}_{m.name}"
            lines.append(f"# HELP {full} {m.help}")
            lines.append(f"# TYPE {full} {m.kind}")
            for values, child in sorted(m._children.items()):
                if m.kind == "histogram":
                    cum = 0
                    for bound, c in zip(m.buckets, child.counts):
                        cum += c
                        lab = self._fmt_labels(m.label_names, values,
                                               f'le="{_fmt(bound)}"')
                        lines.append(f"{full}_bucket{lab} {cum}")
                    cum += child.counts[-1]
                    lab = self._fmt_labels(m.label_names, values, 'le="+Inf"')
                    lines.append(f"{full}_bucket{lab} {cum}")
                    lab = self._fmt_labels(m.label_names, values)
                    lines.append(f"{full}_sum{lab} {_fmt(child.sum)}")
                    lines.append(f"{full}_count{lab} {child.count}")
                else:
                    lab = self._fmt_labels(m.label_names, values)
                    lines.append(f"{full}{lab} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        out: Dict[str, dict] = {}
        for m in self._metrics.values():
            entry: dict = {"type": m.kind, "help": m.help,
                           "labels": list(m.label_names)}
            series = []
            for values, child in sorted(m._children.items()):
                if m.kind == "histogram":
                    series.append({"labels": list(values),
                                   "buckets": list(m.buckets),
                                   "counts": list(child.counts),
                                   "sum": child.sum, "count": child.count})
                else:
                    series.append({"labels": list(values),
                                   "value": child.value})
            entry["series"] = series
            out[f"{self.namespace}_{m.name}"] = entry
        return out

    def write(self, path: str) -> None:
        """JSON for ``.json`` paths, Prometheus text otherwise."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.to_json(), f, indent=2)
        else:
            with open(path, "w") as f:
                f.write(self.to_prometheus())


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"           # metric name
    r"(\{[^}]*\})?"                           # optional label set
    r"\s+(-?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|\+?Inf|NaN))\s*$")


def parse_prometheus(text: str) -> Dict[str, List[Tuple[str, float]]]:
    """Minimal exposition-format parser used by the CI smoke check and the
    tests: returns ``{metric_name: [(label_block, value), ...]}`` and raises
    ``ValueError`` on any malformed line."""
    out: Dict[str, List[Tuple[str, float]]] = {}
    for i, line in enumerate(text.splitlines()):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i + 1}: not a valid sample: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        v = float("inf") if value.lstrip("+") == "Inf" else float(value)
        out.setdefault(name, []).append((labels, v))
    if not out:
        raise ValueError("no samples found")
    return out
