"""FleetPlanner: §5.4 capacity estimation lifted to a replicated fleet.

The single-GPU planner answers "min KV blocks for the SLO"; the fleet
planner answers "min replicas × blocks for a target online SLO *and* a
target offline throughput", replaying the peak window through the full
cluster (router + work stealing + per-replica scheduler/KV manager) on the
virtual clock. The search walks replica counts smallest→largest and, per
count, block budgets smallest→largest — the first configuration meeting
both targets is the recommended fleet.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.cluster.simulator import ClusterSimulator, ClusterStats
from repro.core.block_io import BlockIOSpec, paged_spec
from repro.core.estimator import TimeModel
from repro.core.policies import ECHO, PolicyConfig
from repro.core.request import Request
from repro.core.simulator import clone_requests


@dataclass
class FleetReport:
    min_replicas: Optional[int]
    blocks_per_replica: Optional[int]
    # every probed (replicas, blocks) -> min(TTFT, TPOT) attainment
    slo_by_config: List[Tuple[int, int, float]] = field(default_factory=list)
    # offline throughput of SLO-feasible configs:
    # (replicas, blocks, host_blocks, tok/s)
    throughput_by_config: List[Tuple[int, int, int, float]] = \
        field(default_factory=list)
    offline_throughput: Optional[float] = None
    host_blocks_per_replica: int = 0      # §5.4 extended: host-tier sizing
    host_bytes_per_replica: int = 0       # the same tier in link/RAM bytes


class FleetPlanner:
    def __init__(self, time_model: TimeModel, *,
                 policy: PolicyConfig = ECHO,
                 router_policy: str = "affinity",
                 clock_models: Optional[Sequence] = None,
                 block_size: int = 16, chunk_size: int = 64,
                 max_running: int = 64, seed: int = 0,
                 io_spec: Optional[BlockIOSpec] = None):
        """``clock_models``: per-replica ground-truth hardware profiles
        (cycled across the fleet) — plan over a *mixed-hardware* fleet, e.g.
        ``[TimeModel.a100(), TimeModel.h100()]``, while every replica's
        scheduler starts from the same ``time_model`` estimate (pair with a
        calibrating policy so each replica learns its own hardware).
        ``io_spec`` sets the fleet's block I/O family; host-tier budgets are
        priced through it (a host gigabyte holds far more state snapshots
        than paged KV pages)."""
        self.tm = time_model
        self.policy = policy
        self.router_policy = router_policy
        self.clock_models = list(clock_models) if clock_models else None
        self.block_size = block_size
        self.chunk_size = chunk_size
        self.max_running = max_running
        self.seed = seed
        self.io = io_spec or paged_spec()

    def host_blocks_for_bytes(self, n_bytes: float) -> int:
        """Host-tier slots a byte budget buys under this fleet's family:
        one slot parks one block's payload — ``io.block_bytes(block_size)``
        bytes of KV pages, or one fixed-size snapshot."""
        slot = max(self.io.block_bytes(self.block_size), 1)
        return int(n_bytes // slot)

    # ------------------------------------------------------------- probes
    def simulate(self, online: Sequence[Request], offline: Sequence[Request],
                 n_replicas: int, num_blocks: int, *,
                 host_blocks: int = 0,
                 duration: Optional[float] = None,
                 max_iters: int = 200_000) -> ClusterStats:
        sim = ClusterSimulator(n_replicas, self.policy,
                               router_policy=self.router_policy,
                               num_blocks=num_blocks,
                               block_size=self.block_size,
                               chunk_size=self.chunk_size,
                               max_running=self.max_running, seed=self.seed,
                               time_model=self.tm,
                               clock_models=self.clock_models,
                               host_kv_blocks=host_blocks,
                               io_spec=self.io)
        sim.submit_all(clone_requests(online) + clone_requests(offline))
        return sim.run(max_iters=max_iters, until_time=duration)

    def probe(self, online: Sequence[Request], offline: Sequence[Request],
              n_replicas: int, num_blocks: int, *, host_blocks: int = 0,
              duration: Optional[float] = None) -> Tuple[float, float]:
        """One configuration probe — THE shared sweep primitive under
        ``attainment_curve``, ``plan`` and the autoscaler's sizing oracle:
        replay the workload through a fleet of this shape and return
        (min(TTFT, TPOT) attainment, offline tok/s)."""
        stats = self.simulate(online, offline, n_replicas, num_blocks,
                              host_blocks=host_blocks, duration=duration)
        att = min(stats.slo_attainment("ttft"),
                  stats.slo_attainment("tpot"))
        return att, stats.offline_throughput()

    def attainment_curve(self, online: Sequence[Request], *,
                         candidate_replicas: Sequence[int] = (1, 2, 4),
                         num_blocks: int = 256,
                         duration: Optional[float] = None
                         ) -> List[Tuple[int, float]]:
        """min(TTFT, TPOT) attainment of the online peak vs. replica count
        at a fixed per-replica block budget (monotone non-decreasing: more
        replicas only ever dilute load)."""
        return [(n, self.probe(online, [], n, num_blocks,
                               duration=duration)[0])
                for n in sorted(candidate_replicas)]

    # ------------------------------------------------------------- planning
    def plan(self, online_peak: Sequence[Request],
             offline: Sequence[Request], *,
             candidate_replicas: Sequence[int] = (1, 2, 4),
             candidate_blocks: Sequence[int] = (64, 128, 256),
             candidate_host_blocks: Sequence[int] = (0,),
             candidate_host_bytes: Optional[Sequence[float]] = None,
             slo_target: float = 0.9,
             offline_target: Optional[float] = None,
             duration: Optional[float] = None) -> FleetReport:
        """Step 1: smallest fleet whose online attainment meets the target.
        Step 2: at each SLO-feasible config, measure co-served offline
        throughput; require ``offline_target`` too when given.

        ``candidate_host_blocks`` extends the §5.4 search to the host swap
        tier (replicas x device blocks x host blocks): host memory is far
        cheaper than HBM, so the planner prefers the smallest host tier that
        lifts a device-feasible config over the offline target before
        growing device blocks or the fleet.

        ``candidate_host_bytes`` states the same budgets in RAM bytes and
        overrides ``candidate_host_blocks``: each budget is converted to
        slots through the fleet's I/O family, so the identical byte ladder
        yields many more slots on a state-snapshot fleet than a paged one."""
        if candidate_host_bytes is not None:
            candidate_host_blocks = [self.host_blocks_for_bytes(b)
                                     for b in candidate_host_bytes]
        report = FleetReport(None, None)
        for n in sorted(candidate_replicas):
            for nb in sorted(candidate_blocks):
                att, _ = self.probe(online_peak, [], n, nb,
                                    duration=duration)
                report.slo_by_config.append((n, nb, att))
                if att < slo_target:
                    continue
                for hb in sorted(candidate_host_blocks):
                    _, tput = self.probe(online_peak, offline, n, nb,
                                         host_blocks=hb, duration=duration)
                    report.throughput_by_config.append((n, nb, hb, tput))
                    if offline_target is not None and tput < offline_target:
                        continue    # bigger cache/host tier may lift it
                    report.min_replicas = n
                    report.blocks_per_replica = nb
                    report.host_blocks_per_replica = hb
                    report.host_bytes_per_replica = \
                        hb * self.io.block_bytes(self.block_size)
                    report.offline_throughput = tput
                    return report
        return report
