"""ClusterSimulator: N EchoEngine replicas on one shared virtual clock.

Event loop (deterministic): the next event is either the earliest pending
arrival — dispatched through the Router using replica load at that instant —
or a step of the busy replica with the smallest virtual ``now`` (ties broken
by replica id). Each replica's iteration advances its own clock by the
calibrated TimeModel, exactly the §5.4 single-engine methodology
(core/simulator.py) lifted fleet-wide; periodic ``rebalance`` calls let the
router shed offline work off replicas whose online load spiked.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.cluster.replica import Replica
from repro.cluster.router import Router, RouterStats
from repro.core.block_io import BlockIOSpec
from repro.core.engine import MAX_STALLS, EngineStats
from repro.core.estimator import PerturbedTimeModel, TimeModel
from repro.core.policies import ECHO, PolicyConfig
from repro.core.request import Request, RequestState


@dataclass
class ClusterStats:
    """Fleet-wide aggregate over per-replica EngineStats."""
    replicas: List[EngineStats] = field(default_factory=list)
    router: RouterStats = field(default_factory=RouterStats)
    aborted_undispatched: List[Request] = field(default_factory=list)
    _merged: Optional[EngineStats] = field(default=None, init=False,
                                           repr=False, compare=False)

    def merged(self) -> EngineStats:
        if self._merged is None:
            m = EngineStats()
            for st in self.replicas:
                m.iterations.extend(st.iterations)
                m.finished.extend(st.finished)
                m.aborted.extend(st.aborted)
            m.aborted.extend(self.aborted_undispatched)
            m.iterations.sort(key=lambda rec: rec.t)
            self._merged = m
        return self._merged

    def offline_throughput(self) -> float:
        """Fleet offline throughput: completed offline tokens over the
        offline makespan across all replicas."""
        return self.merged().offline_throughput()

    def slo_attainment(self, kind: str = "ttft") -> float:
        return self.merged().slo_attainment(kind)

    def swap_hidden_frac(self) -> float:
        """Fleet-wide fraction of PCIe swap traffic hidden under compute
        (0.0 when serial or swap-free; see EngineStats.swap_hidden_frac)."""
        return self.merged().swap_hidden_frac()

    def finished_counts(self) -> Tuple[int, int]:
        m = self.merged()
        on = sum(1 for r in m.finished if r.is_online)
        off = len(m.finished) - on
        return on, off

    def per_replica_offline_tokens(self) -> List[int]:
        return [sum(r.prompt_len + r.n_output
                    for r in st.finished if not r.is_online)
                for st in self.replicas]


class ClusterSimulator:
    def __init__(self, n_replicas: int, policy: PolicyConfig = ECHO, *,
                 router_policy: str = "affinity",
                 num_blocks: int = 256, block_size: int = 16,
                 chunk_size: int = 64,
                 time_model: Optional[TimeModel] = None,
                 clock_models: Optional[Sequence] = None,
                 max_batch_tokens: int = 2048, max_running: int = 64,
                 host_kv_blocks: int = 0,
                 io_spec: Optional[BlockIOSpec] = None,
                 seed: int = 0, steal_queue_depth: int = 4,
                 steal_batch: int = 8, rebalance_every: int = 8):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        tm = time_model or TimeModel()
        # Each replica owns a *copy* of the estimate model: with online
        # calibration the estimates drift apart per replica (heterogeneous
        # fleets), and even without it a shared mutable model would couple
        # replicas. ``clock_models`` (cycled when shorter than the fleet)
        # sets per-replica ground-truth hardware profiles; None keeps the
        # classic perfect-estimate simulator.
        def clock_for(i: int):
            if not clock_models:
                return None
            cm = clock_models[i % len(clock_models)]
            if isinstance(cm, PerturbedTimeModel):
                # independent noise streams even when profiles are cycled
                cm = dataclasses.replace(cm, seed=cm.seed + i)
            return cm

        self.replicas = [
            Replica.simulated(i, policy, num_blocks=num_blocks,
                              block_size=block_size, chunk_size=chunk_size,
                              time_model=copy.deepcopy(tm),
                              clock_model=clock_for(i),
                              max_batch_tokens=max_batch_tokens,
                              max_running=max_running,
                              host_kv_blocks=host_kv_blocks, seed=seed + i,
                              io_spec=io_spec)
            for i in range(n_replicas)
        ]
        self.router = Router(self.replicas, policy=router_policy, seed=seed,
                             steal_queue_depth=steal_queue_depth,
                             steal_batch=steal_batch)
        self.rebalance_every = rebalance_every
        self._pending: List[Tuple[float, int, Request]] = []   # arrival heap
        self.aborted_undispatched: List[Request] = []
        self._steps = 0

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        heapq.heappush(self._pending, (req.arrival_time, req.rid, req))

    def submit_all(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # ------------------------------------------------------------- loop
    def _busy(self) -> List[Replica]:
        return [r for r in self.replicas
                if r.has_work() and r.stalls <= MAX_STALLS]

    def step_event(self, until_time: Optional[float] = None) -> bool:
        """Advance the cluster by ONE event — dispatch the earliest pending
        arrival or step the busy replica with the smallest virtual clock.
        Returns False when nothing is left to do (or the next event lies past
        ``until_time``). ``run`` is a loop over this; the serving facade uses
        it as the cluster's low-level stepping primitive."""
        busy = self._busy()
        t_arr = self._pending[0][0] if self._pending else None
        if not busy and t_arr is None:
            return False
        t_busy = min((r.engine.now for r in busy), default=float("inf"))
        t_next = min(t_busy, t_arr) if t_arr is not None else t_busy
        if until_time is not None and t_next >= until_time:
            return False
        if t_arr is not None and t_arr <= t_busy:
            _, _, req = heapq.heappop(self._pending)
            self.router.dispatch(req)
            return True
        rep = min(busy, key=lambda r: (r.engine.now, r.id))
        before = rep.engine.now
        rec = rep.engine.step()
        if rec is None and not rep.engine.pending \
                and rep.engine.now <= before:
            rep.stalls += 1             # unschedulable backlog: back off
        else:
            rep.stalls = 0
        self._steps += 1
        if self._steps % self.rebalance_every == 0:
            self.router.rebalance()
        return True

    def abort(self, req: Request) -> bool:
        """Cancel a request wherever it lives: still undispatched in the
        arrival heap, or inside whichever replica the router placed it on."""
        for i, (_, _, r) in enumerate(self._pending):
            if r is req:
                self._pending.pop(i)
                heapq.heapify(self._pending)
                req.state = RequestState.ABORTED
                self.aborted_undispatched.append(req)
                return True
        return any(rep.engine.abort(req) for rep in self.replicas)

    def run(self, max_iters: int = 200_000,
            until_time: Optional[float] = None) -> ClusterStats:
        for _ in range(max_iters):
            if not self.step_event(until_time):
                break
        return self.stats()

    # ------------------------------------------------------------- results
    def stats(self) -> ClusterStats:
        return ClusterStats(replicas=[r.engine.stats for r in self.replicas],
                            router=self.router.stats,
                            aborted_undispatched=list(
                                self.aborted_undispatched))
