"""ClusterSimulator: a dynamic fleet of EchoEngine replicas on one shared
virtual clock.

Event loop (deterministic): the next event is the earliest of (a) a pending
arrival — dispatched through the Router using replica load at that instant —
(b) a step of the busy replica with the smallest virtual ``now`` (ties broken
by replica id), or (c) a scheduled *fleet event*: a chaos kill/degrade, a
JOINING replica becoming ready, or an autoscaler tick. Each replica's
iteration advances its own clock by the calibrated TimeModel, exactly the
§5.4 single-engine methodology (core/simulator.py) lifted fleet-wide;
periodic ``rebalance`` calls let the router shed offline work off replicas
whose online load spiked.

Membership is dynamic (elastic-fleet refactor): ``add_replica`` provisions a
JOINING replica that comes UP after ``join_delay``; ``drain_replica``
re-dispatches the victim's queued work (shipping parked prefixes over the
fabric) and lets it finish its running batch before going DOWN;
``kill_replica`` evacuates *everything* — KV is lost, so re-dispatched
requests recompute at their new home (online first, offline back through the
router into a surviving pool). ``ChaosConfig`` schedules kills and straggler
degradations; ``ClusterStats`` grows the recovery accounting the elasticity
benchmark gates on.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.replica import Replica, ReplicaState
from repro.cluster.router import Router, RouterStats
from repro.core.block_io import BlockIOSpec
from repro.core.engine import MAX_STALLS, EngineStats
from repro.core.estimator import PerturbedTimeModel, TimeModel
from repro.core.policies import ECHO, PolicyConfig
from repro.core.request import Request, RequestState


@dataclass
class ChaosConfig:
    """Failure/straggler injection schedule for a cluster run.

    ``kills``: (t, replica_id) — the replica dies at t; its in-flight
    requests are re-dispatched (recompute semantics, KV lost).
    ``degrades``: (t, replica_id, slowdown, duration) — the replica's
    ground-truth clock runs ``slowdown``x slower for ``duration`` seconds,
    then restores. Explicit schedules keep runs deterministic; ``sample``
    draws one from seeded rates."""
    kills: List[Tuple[float, int]] = field(default_factory=list)
    degrades: List[Tuple[float, int, float, float]] = \
        field(default_factory=list)
    seed: int = 0

    @classmethod
    def sample(cls, n_replicas: int, duration: float, *, seed: int = 0,
               kill_prob: float = 0.0, degrade_prob: float = 0.0,
               slowdown: float = 3.0,
               degrade_duration: float = 10.0) -> "ChaosConfig":
        """Draw a schedule: each replica independently suffers at most one
        kill (probability ``kill_prob``) or one degradation episode
        (``degrade_prob``), at a uniform instant within the run."""
        rng = np.random.default_rng(seed)
        kills, degrades = [], []
        for i in range(n_replicas):
            u = rng.random()
            t = float(rng.uniform(0.1 * duration, 0.9 * duration))
            if u < kill_prob:
                kills.append((t, i))
            elif u < kill_prob + degrade_prob:
                degrades.append((t, i, slowdown, degrade_duration))
        return cls(kills=kills, degrades=degrades, seed=seed)


@dataclass
class KillRecord:
    """Recovery accounting for one replica kill."""
    t: float
    replica_id: int
    redispatched_online: int
    redispatched_offline: int
    lost_tokens: int               # computed KV tokens discarded at the kill
    rids: List[int] = field(default_factory=list)


@dataclass
class ClusterStats:
    """Fleet-wide aggregate over per-replica EngineStats."""
    replicas: List[EngineStats] = field(default_factory=list)
    router: RouterStats = field(default_factory=RouterStats)
    aborted_undispatched: List[Request] = field(default_factory=list)
    kills: List[KillRecord] = field(default_factory=list)
    lifecycle: List[Tuple[float, int, str]] = field(default_factory=list)
    replica_seconds: float = 0.0   # fleet cost: sum of UP..DOWN spans
    _merged: Optional[EngineStats] = field(default=None, init=False,
                                           repr=False, compare=False)

    def merged(self) -> EngineStats:
        if self._merged is None:
            m = EngineStats()
            for st in self.replicas:
                m.iterations.extend(st.iterations)
                m.finished.extend(st.finished)
                m.aborted.extend(st.aborted)
            m.aborted.extend(self.aborted_undispatched)
            m.iterations.sort(key=lambda rec: rec.t)
            self._merged = m
        return self._merged

    def offline_throughput(self) -> float:
        """Fleet offline throughput: completed offline tokens over the
        offline makespan across all replicas."""
        return self.merged().offline_throughput()

    def slo_attainment(self, kind: str = "ttft") -> float:
        return self.merged().slo_attainment(kind)

    def swap_hidden_frac(self) -> float:
        """Fleet-wide fraction of PCIe swap traffic hidden under compute
        (0.0 when serial or swap-free; see EngineStats.swap_hidden_frac)."""
        return self.merged().swap_hidden_frac()

    def finished_counts(self) -> Tuple[int, int]:
        m = self.merged()
        on = sum(1 for r in m.finished if r.is_online)
        off = len(m.finished) - on
        return on, off

    def per_replica_offline_tokens(self) -> List[int]:
        return [sum(r.prompt_len + r.n_output
                    for r in st.finished if not r.is_online)
                for st in self.replicas]

    # -------------------------------------------------------- recovery
    @property
    def redispatched_online(self) -> int:
        return sum(k.redispatched_online for k in self.kills)

    @property
    def redispatched_offline(self) -> int:
        return sum(k.redispatched_offline for k in self.kills)

    @property
    def lost_tokens(self) -> int:
        return sum(k.lost_tokens for k in self.kills)

    def recovery_latencies(self) -> List[float]:
        """Kill-to-finish seconds of every re-dispatched request that did
        finish — the tail of these is what a mid-run failure costs."""
        by_rid = {r.rid: r for r in self.merged().finished}
        out: List[float] = []
        for k in self.kills:
            for rid in k.rids:
                r = by_rid.get(rid)
                if r is not None and r.finish_time is not None:
                    out.append(r.finish_time - k.t)
        return out


class ClusterSimulator:
    def __init__(self, n_replicas: int, policy: PolicyConfig = ECHO, *,
                 router_policy: str = "affinity",
                 num_blocks: int = 256, block_size: int = 16,
                 chunk_size: int = 64,
                 time_model: Optional[TimeModel] = None,
                 clock_models: Optional[Sequence] = None,
                 max_batch_tokens: int = 2048, max_running: int = 64,
                 host_kv_blocks: int = 0,
                 io_spec: Optional[BlockIOSpec] = None,
                 seed: int = 0, steal_queue_depth: int = 4,
                 steal_batch: int = 8, rebalance_every: int = 8,
                 chaos: Optional[ChaosConfig] = None,
                 autoscaler=None, join_delay: float = 1.0,
                 migrate: bool = True):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        tm = time_model or TimeModel()
        # Each replica owns a *copy* of the estimate model: with online
        # calibration the estimates drift apart per replica (heterogeneous
        # fleets), and even without it a shared mutable model would couple
        # replicas. ``clock_models`` (cycled when shorter than the fleet)
        # sets per-replica ground-truth hardware profiles; None keeps the
        # classic perfect-estimate simulator. The factory parameters are
        # kept so ``add_replica`` can provision identical members later.
        self._policy = policy
        self._tm_template = tm
        self._clock_models = clock_models
        self._factory_kw = dict(num_blocks=num_blocks, block_size=block_size,
                                chunk_size=chunk_size,
                                max_batch_tokens=max_batch_tokens,
                                max_running=max_running,
                                host_kv_blocks=host_kv_blocks,
                                io_spec=io_spec)
        self._seed = seed
        self.replicas = [self._make_replica(i) for i in range(n_replicas)]
        self._next_id = n_replicas
        self.migrate = migrate
        self.join_delay = join_delay
        self.router = Router(self.replicas, policy=router_policy, seed=seed,
                             steal_queue_depth=steal_queue_depth,
                             steal_batch=steal_batch, migrate=migrate)
        self.rebalance_every = rebalance_every
        self._pending: List[Tuple[float, int, Request]] = []   # arrival heap
        self.aborted_undispatched: List[Request] = []
        self._steps = 0
        self.now = 0.0                 # latest event instant processed
        # fleet events: (t, seq, kind, payload) — chaos kills/degrades,
        # join-ready transitions, autoscaler ticks
        self._events: List[Tuple[float, int, str, tuple]] = []
        self._eseq = itertools.count()
        self.kills: List[KillRecord] = []
        self.lifecycle_log: List[Tuple[float, int, str]] = []
        # observability tap (repro.obs.trace sets this): every lifecycle
        # transition as (replica_id, state_name, t)
        self.on_lifecycle: Optional[Callable[[int, str, float], None]] = None
        self.chaos = chaos
        if chaos is not None:
            for t, rid in chaos.kills:
                self._push_event(t, "kill", (rid,))
            for t, rid, factor, dur in chaos.degrades:
                self._push_event(t, "degrade", (rid, factor))
                self._push_event(t + dur, "restore", (rid,))
        self.autoscaler = autoscaler
        if autoscaler is not None:
            autoscaler.bind(self)
            self._push_event(autoscaler.interval, "autoscale", ())

    def _make_replica(self, i: int,
                      state: ReplicaState = ReplicaState.UP) -> Replica:
        def clock_for(idx: int):
            if not self._clock_models:
                return None
            cm = self._clock_models[idx % len(self._clock_models)]
            if isinstance(cm, PerturbedTimeModel):
                # independent noise streams even when profiles are cycled
                cm = dataclasses.replace(cm, seed=cm.seed + idx)
            return cm

        return Replica.simulated(i, self._policy,
                                 time_model=copy.deepcopy(self._tm_template),
                                 clock_model=clock_for(i),
                                 seed=self._seed + i, state=state,
                                 **self._factory_kw)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        heapq.heappush(self._pending, (req.arrival_time, req.rid, req))

    def submit_all(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # --------------------------------------------------------- membership
    def _lifecycle(self, rep: Replica, t: float) -> None:
        self.lifecycle_log.append((t, rep.id, rep.state.value))
        if self.on_lifecycle is not None:
            self.on_lifecycle(rep.id, rep.state.value, t)

    def _by_id(self, replica_id: int) -> Replica:
        for rep in self.replicas:
            if rep.id == replica_id:
                return rep
        raise KeyError(f"no replica {replica_id} in the fleet")

    def _push_event(self, t: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def add_replica(self, now: Optional[float] = None) -> Replica:
        """Provision a new JOINING replica; it becomes UP (routable) after
        ``join_delay`` seconds of cluster time."""
        now = self.now if now is None else now
        rep = self._make_replica(self._next_id, state=ReplicaState.JOINING)
        self._next_id += 1
        rep.engine.now = now
        rep.ready_time = now + self.join_delay
        self.replicas.append(rep)        # the router holds this same list
        self._push_event(rep.ready_time, "join_ready", (rep.id,))
        self._lifecycle(rep, now)
        return rep

    def drain_replica(self, replica_id: int,
                      now: Optional[float] = None) -> bool:
        """Gracefully remove a replica: it takes no new work, its *queued*
        requests are re-dispatched through the router (parked prefixes
        shipped over the fabric when ``migrate``), its running batch
        finishes locally, and the event loop marks it DOWN once empty.
        Refuses (returns False) when it is the last routable replica."""
        rep = self._by_id(replica_id)
        if not rep.routable and rep.state != ReplicaState.JOINING:
            return False
        live = self.router.routable()
        if len(live) <= 1 and rep in live:
            return False                 # never drain the last home of work
        now = self.now if now is None else now
        rep.restore()                    # unwrap any straggler clock
        rep.begin_drain()
        self._lifecycle(rep, now)
        for req in rep.evacuate(include_running=False):
            target = self.router.dispatch(req)
            if self.migrate and not req.is_online and target is not rep:
                self.router.migrate_prefix(rep, target, req)
        return True

    def kill_replica(self, replica_id: int,
                     now: Optional[float] = None) -> Optional[KillRecord]:
        """Fail a replica abruptly: its KV (device and host tier) is lost
        and every in-flight request is re-dispatched with recompute
        semantics — online first through SLO-aware placement, offline back
        into a surviving pool. With no routable survivor the requests
        re-enter the arrival heap and dispatch when a JOINING replica comes
        up. Returns the recovery record (None if already DOWN)."""
        rep = self._by_id(replica_id)
        if rep.state == ReplicaState.DOWN:
            return None
        now = self.now if now is None else now
        lost = sum(r.computed_tokens
                   for r in rep.inflight_requests(include_running=True))
        evacuated = rep.evacuate(include_running=True)
        rep.mark_down(now)
        self._lifecycle(rep, now)
        n_online = sum(1 for r in evacuated if r.is_online)
        record = KillRecord(t=now, replica_id=rep.id,
                            redispatched_online=n_online,
                            redispatched_offline=len(evacuated) - n_online,
                            lost_tokens=lost,
                            rids=[r.rid for r in evacuated])
        self.kills.append(record)
        if self.router.routable():
            for req in evacuated:        # online first (evacuate's order)
                self.router.dispatch(req)
        else:
            for req in evacuated:
                heapq.heappush(self._pending,
                               (max(req.arrival_time, now), req.rid, req))
        return record

    def degrade_replica(self, replica_id: int, slowdown: float,
                        now: Optional[float] = None) -> None:
        rep = self._by_id(replica_id)
        if rep.state == ReplicaState.DOWN:
            return
        now = self.now if now is None else now
        rep.degrade(slowdown)
        self._lifecycle(rep, now)

    def restore_replica(self, replica_id: int,
                        now: Optional[float] = None) -> None:
        rep = self._by_id(replica_id)
        if rep.state != ReplicaState.DEGRADED:
            return
        now = self.now if now is None else now
        rep.restore()
        self._lifecycle(rep, now)

    def _apply_event(self, t: float, kind: str, payload: tuple) -> None:
        if kind == "kill":
            self.kill_replica(payload[0], t)
        elif kind == "degrade":
            self.degrade_replica(payload[0], payload[1], t)
        elif kind == "restore":
            self.restore_replica(payload[0], t)
        elif kind == "join_ready":
            rep = self._by_id(payload[0])
            if rep.state == ReplicaState.JOINING:
                rep.mark_up(t)
                self._lifecycle(rep, t)
        elif kind == "autoscale":
            if self.autoscaler is not None:
                self.autoscaler.tick(t)
                self._push_event(t + self.autoscaler.interval,
                                 "autoscale", ())

    # ------------------------------------------------------------- loop
    def _busy(self) -> List[Replica]:
        return [r for r in self.replicas
                if r.state != ReplicaState.DOWN
                and r.has_work() and r.stalls <= MAX_STALLS]

    def _sweep_drained(self) -> None:
        for rep in self.replicas:
            if rep.state == ReplicaState.DRAINING and not rep.has_work():
                rep.engine.flush_swaps()
                rep.mark_down(max(rep.engine.now, self.now))
                self._lifecycle(rep, rep.t_down)

    def step_event(self, until_time: Optional[float] = None) -> bool:
        """Advance the cluster by ONE event — dispatch the earliest pending
        arrival, apply the earliest fleet event (chaos / join / autoscale
        tick), or step the busy replica with the smallest virtual clock.
        Returns False when nothing is left to do (or the next event lies past
        ``until_time``). ``run`` is a loop over this; the serving facade uses
        it as the cluster's low-level stepping primitive."""
        self._sweep_drained()
        busy = self._busy()
        t_arr = self._pending[0][0] if self._pending else None
        if not busy and t_arr is None:
            # fleet events alone cannot create work: nothing left to do
            return False
        t_busy = min((r.engine.now for r in busy), default=float("inf"))
        t_evt = self._events[0][0] if self._events else float("inf")
        t_next = min(t_busy, t_evt) if t_arr is None \
            else min(t_busy, t_evt, t_arr)
        if until_time is not None and t_next >= until_time:
            return False
        self.now = max(self.now, t_next)
        if t_evt <= t_busy and (t_arr is None or t_evt <= t_arr):
            t, _, kind, payload = heapq.heappop(self._events)
            self._apply_event(t, kind, payload)
            return True
        if t_arr is not None and t_arr <= t_busy:
            if not self.router.routable():
                # hold the arrival: a pending fleet event may bring a
                # JOINING replica up, and draining replicas still need to
                # finish — otherwise the fleet is dead and we stop
                if self._events:
                    return self._pop_apply_event()
                if busy:
                    return self._step_busy(busy)
                return False
            _, _, req = heapq.heappop(self._pending)
            if self.autoscaler is not None and req.is_online:
                self.autoscaler.observe_arrival(req.arrival_time)
            self.router.dispatch(req)
            return True
        return self._step_busy(busy)

    def _pop_apply_event(self) -> bool:
        t, _, kind, payload = heapq.heappop(self._events)
        self.now = max(self.now, t)
        self._apply_event(t, kind, payload)
        return True

    def _step_busy(self, busy: List[Replica]) -> bool:
        if not busy:
            return False
        rep = min(busy, key=lambda r: (r.engine.now, r.id))
        before = rep.engine.now
        rec = rep.engine.step()
        if rec is None and not rep.engine.pending \
                and rep.engine.now <= before:
            rep.stalls += 1             # unschedulable backlog: back off
        else:
            rep.stalls = 0
        self.now = max(self.now, rep.engine.now)
        self._steps += 1
        if self._steps % self.rebalance_every == 0:
            self.router.rebalance()
        return True

    def abort(self, req: Request) -> bool:
        """Cancel a request wherever it lives: still undispatched in the
        arrival heap, or inside whichever replica the router placed it on."""
        for i, (_, _, r) in enumerate(self._pending):
            if r is req:
                self._pending.pop(i)
                heapq.heapify(self._pending)
                req.state = RequestState.ABORTED
                self.aborted_undispatched.append(req)
                return True
        return any(rep.engine.abort(req) for rep in self.replicas)

    def run(self, max_iters: int = 200_000,
            until_time: Optional[float] = None) -> ClusterStats:
        for _ in range(max_iters):
            if not self.step_event(until_time):
                break
        self._sweep_drained()
        return self.stats()

    # ------------------------------------------------------------- results
    def fleet_now(self) -> float:
        """Latest instant the cluster has reached."""
        return max([self.now] + [r.engine.now for r in self.replicas])

    def replica_seconds(self) -> float:
        now = self.fleet_now()
        return sum(rep.replica_seconds(now) for rep in self.replicas)

    def stats(self) -> ClusterStats:
        return ClusterStats(replicas=[r.engine.stats for r in self.replicas],
                            router=self.router.stats,
                            aborted_undispatched=list(
                                self.aborted_undispatched),
                            kills=list(self.kills),
                            lifecycle=list(self.lifecycle_log),
                            replica_seconds=self.replica_seconds())
