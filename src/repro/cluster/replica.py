"""Replica: one EchoEngine plus the load signals a cluster router reads.

A replica exports four signal families (ISSUE: cluster-scale co-serving):
  * online pressure     — queue depth + TimeModel-predicted added latency
  * memory headroom     — free KV blocks and eviction-threshold slack
  * offline backlog     — pooled + pending + running offline work
  * prefix locality     — the OfflinePool radix summary merged with what the
                          BlockManager actually holds cached, keyed by the
                          first-block chain hash of each document group

Replicas carry an explicit lifecycle (elastic-fleet refactor):

    JOINING -> UP <-> DEGRADED
                 \\-> DRAINING -> DOWN       (and UP/DEGRADED -> DOWN on kill)

Only UP/DEGRADED replicas are *routable*. DEGRADED wraps the ground-truth
clock in a ``DegradedClock`` slowdown (a straggler) without touching the
scheduler's estimate — the damage surfaces as clock skew, which the
router's ``predicted_added_latency`` already penalizes. DRAINING replicas
take no new work and go DOWN once empty; a killed replica's in-flight
requests are evacuated (KV reset) for re-dispatch elsewhere.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.block_io import BlockIOSpec
from repro.core.block_manager import chain_hash, prefix_chain
from repro.core.engine import EchoEngine
from repro.core.estimator import DegradedClock, TimeModel
from repro.core.policies import ECHO, PolicyConfig
from repro.core.request import Request, RequestState


class ReplicaState(enum.Enum):
    JOINING = "joining"        # provisioning; not routable yet
    UP = "up"                  # healthy, routable
    DEGRADED = "degraded"      # straggler: routable, clock runs slow
    DRAINING = "draining"      # no new work; finishes what it holds
    DOWN = "down"              # out of the fleet (drained or killed)


def first_block_hash(req: Request, block_size: int) -> Optional[int]:
    """Top-level radix group key of a request (None if under one block)."""
    if len(req.prompt) < block_size:
        return None
    return chain_hash(0, tuple(req.prompt[:block_size]))


@dataclass
class ReplicaLoad:
    """Point-in-time snapshot of one replica's signals (for reporting)."""
    replica_id: int
    now: float
    online_queue: int
    running_online: int
    running_offline: int
    offline_backlog: int
    free_blocks: int
    threshold_headroom: int
    prefix_groups: Dict[int, int] = field(default_factory=dict)


class Replica:
    def __init__(self, replica_id: int, engine: EchoEngine,
                 state: "ReplicaState" = ReplicaState.UP):
        self.id = replica_id
        self.engine = engine
        self.stalls = 0            # consecutive no-progress steps (see sim)
        self.stolen_in = 0
        self.stolen_out = 0
        self.state = state
        self.slowdown = 1.0        # DEGRADED clock factor (1.0 = healthy)
        self.ready_time: Optional[float] = None   # JOINING -> UP instant
        self.t_up: Optional[float] = (0.0 if state == ReplicaState.UP
                                      else None)
        self.t_down: Optional[float] = None

    # ------------------------------------------------------------ lifecycle
    @property
    def routable(self) -> bool:
        """May the router place new work here? (UP or DEGRADED only —
        JOINING replicas are not ready, DRAINING/DOWN take no new work.)"""
        return self.state in (ReplicaState.UP, ReplicaState.DEGRADED)

    def mark_up(self, now: float) -> None:
        """JOINING -> UP: the replica's cold engine starts at cluster time
        (its virtual clock cannot lag the fleet it just joined)."""
        self.state = ReplicaState.UP
        self.ready_time = None
        if self.t_up is None:
            self.t_up = now
        self.engine.now = max(self.engine.now, now)

    def degrade(self, factor: float) -> None:
        """UP -> DEGRADED (or re-degrade): wrap the ground-truth clock so
        every observed iteration runs ``factor``x slower. The scheduler's
        estimate is untouched — a straggler does not know it is one."""
        if factor <= 1.0:
            self.restore()
            return
        base = self.engine.clock_model
        if isinstance(base, DegradedClock):
            base = base.base
        self.engine.clock_model = DegradedClock(base, slowdown=factor)
        self.slowdown = factor
        if self.state == ReplicaState.UP:
            self.state = ReplicaState.DEGRADED

    def restore(self) -> None:
        """DEGRADED -> UP: unwrap the slowdown."""
        if isinstance(self.engine.clock_model, DegradedClock):
            self.engine.clock_model = self.engine.clock_model.base
        self.slowdown = 1.0
        if self.state == ReplicaState.DEGRADED:
            self.state = ReplicaState.UP

    def begin_drain(self) -> None:
        """UP/DEGRADED -> DRAINING: no new dispatches; the replica keeps
        stepping until it holds no work, then the simulator marks it DOWN."""
        if self.state in (ReplicaState.UP, ReplicaState.DEGRADED,
                          ReplicaState.JOINING):
            self.state = ReplicaState.DRAINING

    def mark_down(self, now: float) -> None:
        self.state = ReplicaState.DOWN
        if self.t_down is None:
            self.t_down = now

    def replica_seconds(self, now: float) -> float:
        """Seconds this replica has been serving (UP instant to DOWN instant
        or ``now``) — the cost side of the autoscaling benchmark."""
        if self.t_up is None:
            return 0.0
        end = self.t_down if self.t_down is not None else now
        return max(end - self.t_up, 0.0)

    # ----------------------------------------------------------- evacuation
    def inflight_requests(self, include_running: bool = True
                          ) -> List[Request]:
        """Every unfinished request this replica is responsible for, online
        first (the re-dispatch order): scheduler queue, pending intake,
        radix pool, and — when ``include_running`` — the running batch."""
        eng = self.engine
        sched = eng.scheduler
        online: List[Request] = list(sched.online_queue)
        online += [r for r in eng.pending if r.is_online]
        offline: List[Request] = [r for r in eng.pending if not r.is_online]
        offline += list(self.engine.pool.requests())
        if include_running:
            online += [r for r in sched.running if r.is_online]
            offline += [r for r in sched.running if not r.is_online]
        return online + offline

    def evacuate(self, include_running: bool = True) -> List[Request]:
        """Pull unfinished requests out of this replica for re-dispatch
        elsewhere, releasing every resource they held here (KV blocks,
        owner pins, pool membership, runner state) and resetting their
        compute progress — exactly recompute-preemption semantics, so
        generated tokens are kept and re-prefilled at the new home and
        ``_fabricate``'s (rid, n_output) seeding continues deterministically.
        Online requests come first. With ``include_running=False`` (drain)
        the running batch stays and finishes here."""
        eng = self.engine
        sched = eng.scheduler
        out = self.inflight_requests(include_running)
        for req in out:
            if req in sched.online_queue:
                sched.online_queue.remove(req)
            if req in eng.pending:
                eng.pending.remove(req)
            if req in eng.pool:
                eng.pool.remove(req)
            if req in sched.running:
                sched.running.remove(req)
            if req.block_ids:
                eng.bm.free_request(req, eng.now, finished=True)
            eng.bm.release_owner_pins(req)
            if eng.runner is not None:
                eng.runner.release(req.rid)
            req.computed_tokens = 0
            req.prefill_target_len = 0
            req.state = RequestState.WAITING
        return out

    @classmethod
    def simulated(cls, replica_id: int, policy: PolicyConfig = ECHO, *,
                  num_blocks: int = 256, block_size: int = 16,
                  chunk_size: int = 64, time_model: Optional[TimeModel] = None,
                  clock_model=None,
                  max_batch_tokens: int = 2048, max_running: int = 64,
                  host_kv_blocks: int = 0, seed: int = 0,
                  io_spec: Optional[BlockIOSpec] = None,
                  state: "ReplicaState" = ReplicaState.UP) -> "Replica":
        """``time_model`` is this replica's *estimate* (what its scheduler
        believes); ``clock_model`` its ground-truth hardware profile — pass
        different ones per replica for a heterogeneous/miscalibrated fleet.
        ``host_kv_blocks`` sizes this replica's host KV swap tier and
        ``io_spec`` sets its block I/O family (paged KV pages vs. fixed-size
        state snapshots) — transfers are priced by the family's bytes."""
        eng = EchoEngine(None, None, policy, num_blocks=num_blocks,
                         block_size=block_size, chunk_size=chunk_size,
                         time_model=time_model, clock_model=clock_model,
                         clock="virtual",
                         seed=seed, max_batch_tokens=max_batch_tokens,
                         max_running=max_running,
                         host_kv_blocks=host_kv_blocks, io_spec=io_spec)
        return cls(replica_id, eng, state=state)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.engine.submit(req)
        self.stalls = 0            # new work can unblock a drained replica

    # ------------------------------------------------------------- signals
    # (accounting lives on the engine — shared with serving backends)
    def has_work(self) -> bool:
        return self.engine.has_work()

    def online_queue_depth(self) -> int:
        return self.engine.online_queue_depth()

    def offline_backlog(self) -> int:
        return self.engine.offline_backlog()

    def threshold_headroom(self) -> int:
        bm = self.engine.bm
        return max(bm.threshold_blocks - bm.running_blocks, 0)

    def prefix_summary(self) -> Dict[int, int]:
        return self.engine.pool.prefix_summary()

    def host_prefix_blocks(self, req: Request,
                           chain: Optional[List[int]] = None) -> int:
        """Blocks of ``req``'s leading prefix parked on this replica's HOST
        tier beyond what is device-resident — prefix locality that survives
        an online burst flushing the device cache, restorable over PCIe
        instead of recomputed. A routing signal the device-only probe
        misses entirely. The router precomputes the request's hash
        ``chain`` once and shares it across replicas (the hashes are
        replica-independent; only residency differs)."""
        bm = self.engine.bm
        if bm.host is None or not bm.host.blocks:
            return 0
        if chain is None:
            chain = prefix_chain(req.full_tokens, bm.block_size)
        return bm.host_chain_blocks(chain, bm.device_chain_blocks(chain))

    def host_prefix_bytes(self, req: Request,
                          chain: Optional[List[int]] = None) -> int:
        """Link bytes to restore ``req``'s host-parked prefix, priced by
        this replica's block I/O family: a paged replica uploads every
        token's KV pages, a state-family replica uploads one fixed-size
        snapshot regardless of prefix depth (restore_last_only). The router
        uses this as a cost tie-break — equal block counts parked on a
        paged and a state replica are NOT equal link traffic."""
        bm = self.engine.bm
        blocks = self.host_prefix_blocks(req, chain)
        if blocks <= 0:
            return 0
        return bm.io.restore_bytes(blocks * bm.block_size, bm.block_size)

    def affinity(self, group_hash: Optional[int],
                 req: Optional[Request] = None,
                 chain: Optional[List[int]] = None) -> int:
        """How much of this document group the replica already holds:
        pooled members + in-flight members + the request's prefix blocks
        resident in the KV tiers. Given the candidate ``req`` itself, both
        tiers are counted *symmetrically at 1 per block* — device-cached
        blocks (reusable for free) and host-parked blocks (restorable over
        PCIe), device first in the chain walk, so a replica holding the
        document in device cache always scores at least as high as one
        that would have to swap it back in. Work stealing and the router
        thus steer work toward held KV wherever it lives. Without ``req``
        (legacy single-signal probe) the first block contributes +1 per
        tier it is resident in."""
        if group_hash is None:
            return 0
        eng = self.engine
        bs = eng.bm.block_size
        n = eng.pool.group_count(group_hash)
        for r in eng.pending:
            if not r.is_online and first_block_hash(r, bs) == group_hash:
                n += 1
        for r in eng.scheduler.running:
            if not r.is_online and first_block_hash(r, bs) == group_hash:
                n += 1
        if req is not None:
            if chain is None:
                chain = prefix_chain(req.full_tokens, bs)
            dev = eng.bm.device_chain_blocks(chain)
            n += dev + eng.bm.host_chain_blocks(chain, dev)
        else:
            if group_hash in eng.bm.hash_to_bid:
                n += 1
            if eng.bm.host is not None and group_hash in eng.bm.host:
                n += 1                 # first block parked host-side
        return n

    def predicted_added_latency(self, req: Request) -> float:
        """Replica-local time to this request's first token if placed here
        (see ``EchoEngine.predicted_first_token_latency``). Uses this
        replica's own — possibly online-calibrated — estimate model, so a
        slower (or drifted) replica correctly reports longer predicted
        latency to the router."""
        return self.engine.predicted_first_token_latency(req)

    def load(self) -> ReplicaLoad:
        sched = self.engine.scheduler
        return ReplicaLoad(
            replica_id=self.id,
            now=self.engine.now,
            online_queue=self.online_queue_depth(),
            running_online=sum(1 for r in sched.running if r.is_online),
            running_offline=sum(1 for r in sched.running if not r.is_online),
            offline_backlog=self.offline_backlog(),
            free_blocks=self.engine.bm.free_blocks,
            threshold_headroom=self.threshold_headroom(),
            prefix_groups=self.prefix_summary(),
        )

    # ------------------------------------------------------------- stealing
    def steal_offline(self, max_n: int) -> List[Request]:
        """Yield up to ``max_n`` pooled (not yet admitted) offline requests,
        whole loner groups first so the locality damage is minimal — the
        groups this replica holds most of stay home."""
        pool = self.engine.pool
        bs = self.engine.bm.block_size
        groups: Dict[int, List[Request]] = {}
        for req in pool.requests():
            key = pool.group_of(req)
            groups.setdefault(key if key is not None else -req.rid,
                              []).append(req)
        for req in self.engine.pending:           # dispatched, not yet pulled
            if not req.is_online:
                key = first_block_hash(req, bs)
                groups.setdefault(key if key is not None else -req.rid,
                                  []).append(req)
        out: List[Request] = []
        order = sorted(groups.values(),
                       key=lambda rs: (len(rs), min(r.rid for r in rs)))
        for reqs in order:
            for req in reqs:
                if len(out) >= max_n:
                    break
                if req in self.engine.pending:
                    self.engine.pending.remove(req)
                else:
                    pool.remove(req)
                out.append(req)
            if len(out) >= max_n:
                break
        self.stolen_out += len(out)
        return out
