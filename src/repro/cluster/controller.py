"""FleetController: predictive autoscaling over the elastic fleet.

The controller closes the loop between §5.3's RatePredictor and the dynamic
membership operations: each tick it predicts the near-term online arrival
rate (mu + k·sigma over a sliding window), converts it into a desired
replica count through a per-replica capacity figure, and adds JOINING
replicas or drains the idlest one. The capacity figure comes from the same
sweep oracle the offline FleetPlanner uses (``FleetPlanner.probe``): replay
a single-replica peak and find the highest rate one replica sustains at the
SLO target — autoscaling is just capacity planning run continuously.

A reactive backstop rides the predictor: when the mean routable online
queue depth crosses ``queue_high`` the controller scales up even if the
predicted rate says otherwise (predictors lag bursts; queues do not).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.cluster.replica import ReplicaState
from repro.core.estimator import RatePredictor
from repro.core.request import Request


@dataclass
class FleetController:
    """Attach via ``ClusterSimulator(..., autoscaler=FleetController(...))``;
    the simulator schedules a tick every ``interval`` virtual seconds and
    feeds every online arrival into the predictor at dispatch time."""
    min_replicas: int = 1
    max_replicas: int = 4
    rate_per_replica: Optional[float] = None   # req/s one replica sustains
    interval: float = 5.0          # seconds between control ticks
    headroom: float = 1.2          # provision for 20% above predicted rate
    cooldown: float = 10.0         # min seconds between membership changes
    queue_high: int = 4            # reactive backstop: mean online queue
    window: float = 120.0          # predictor sliding window
    k_sigma: float = 2.0
    bin_s: float = 5.0             # predictor bin (match control cadence)
    decisions: List[Tuple[float, str, int]] = field(default_factory=list)
    rate_pred: RatePredictor = field(init=False)

    def __post_init__(self) -> None:
        self.rate_pred = RatePredictor(window=self.window,
                                       k_sigma=self.k_sigma)
        self._sim = None
        self._last_change = -math.inf

    # ------------------------------------------------------------- wiring
    def bind(self, sim) -> None:
        self._sim = sim

    def observe_arrival(self, t: float) -> None:
        self.rate_pred.observe(t)

    # ------------------------------------------------------------- sizing
    def calibrate(self, planner, online_sample: Sequence[Request], *,
                  num_blocks: int = 256, slo_target: float = 0.9,
                  duration: Optional[float] = None) -> float:
        """Derive ``rate_per_replica`` from the planner's sweep oracle:
        replay the sample through ONE replica (``FleetPlanner.probe``) and
        take its arrival rate if the SLO held, else scale it down by how
        many replicas ``plan`` says the sample needs. Returns the figure."""
        arrivals = sorted(r.arrival_time for r in online_sample)
        span = max(arrivals[-1] - arrivals[0], 1e-9) if len(arrivals) > 1 \
            else 1.0
        rate = len(arrivals) / span
        att, _ = planner.probe(online_sample, [], 1, num_blocks,
                               duration=duration)
        if att >= slo_target:
            self.rate_per_replica = rate
        else:
            report = planner.plan(
                online_sample, [],
                candidate_replicas=tuple(
                    range(1, max(self.max_replicas, 2) + 1)),
                candidate_blocks=(num_blocks,), slo_target=slo_target,
                duration=duration)
            need = report.min_replicas or self.max_replicas
            self.rate_per_replica = rate / max(need, 1)
        return self.rate_per_replica

    def desired_replicas(self, now: float) -> int:
        rate = self.rate_pred.predict_rate(now, bin_s=self.bin_s)
        if not self.rate_per_replica or self.rate_per_replica <= 0:
            return self.min_replicas
        need = math.ceil(rate * self.headroom / self.rate_per_replica)
        return max(self.min_replicas, min(need, self.max_replicas))

    # ------------------------------------------------------------- control
    def tick(self, now: float) -> None:
        sim = self._sim
        if sim is None:
            return
        live = [r for r in sim.replicas
                if r.routable or r.state == ReplicaState.JOINING]
        n = len(live)
        want = self.desired_replicas(now)
        routable = sim.router.routable()
        if routable:
            qdepth = sum(r.online_queue_depth() for r in routable) \
                / len(routable)
            if qdepth > self.queue_high:
                want = max(want, min(n + 1, self.max_replicas))
        if now - self._last_change < self.cooldown or want == n:
            return
        if want > n:
            for _ in range(want - n):
                sim.add_replica(now)
            self.decisions.append((now, "add", want - n))
            self._last_change = now
        else:
            # drain only truly idle replicas — never cut a queue loose
            idle = [r for r in routable
                    if r.online_queue_depth() == 0 and not r.has_work()]
            idle.sort(key=lambda r: (r.offline_backlog(), -r.id))
            dropped = 0
            for rep in idle[:n - want]:
                if sim.drain_replica(rep.id, now):
                    dropped += 1
            if dropped:
                self.decisions.append((now, "drain", dropped))
                self._last_change = now

    # ------------------------------------------------------------- results
    @property
    def n_added(self) -> int:
        return sum(k for _, op, k in self.decisions if op == "add")

    @property
    def n_drained(self) -> int:
        return sum(k for _, op, k in self.decisions if op == "drain")
