"""Cluster router: SLO-aware online placement + prefix-affinity offline
dispatch + work-stealing rebalancing.

Online requests always go to the replica with the lowest TimeModel-predicted
added latency (least-loaded in SLO terms) — online placement never degrades
to serve offline locality. Offline tasks are dispatched by the configured
policy:

  affinity     — route to the replica already holding the request's document
                 group (pooled peers, in-flight peers, or the cached prefix
                 itself); new groups go to the least-backlogged replica.
  round_robin  — cycle over replicas (the scatter baseline).
  random       — uniform random replica (seeded).

When a replica's online load spikes, ``rebalance`` sheds pooled offline work
(whole loner groups first) to the calmest replica — HyGen-style elastic
co-location: offline flows to wherever online load is momentarily low.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.cluster.replica import Replica, first_block_hash
from repro.core.block_manager import prefix_chain
from repro.core.request import Request

ROUTER_POLICIES = ("affinity", "round_robin", "random")


@dataclass
class RouterStats:
    online_dispatched: int = 0
    offline_dispatched: int = 0
    affinity_hits: int = 0         # offline dispatches that found a home group
    steals: int = 0                # rebalance events
    stolen_requests: int = 0
    steal_affinity_hits: int = 0   # stolen requests placed onto held KV
    migrations: int = 0            # cross-replica prefix shipments
    migrated_blocks: int = 0
    migrated_bytes: int = 0        # fabric bytes actually admitted
    per_replica_online: dict = field(default_factory=dict)
    per_replica_offline: dict = field(default_factory=dict)


class Router:
    def __init__(self, replicas: Sequence[Replica], *,
                 policy: str = "affinity", seed: int = 0,
                 steal_queue_depth: int = 4, steal_batch: int = 8,
                 migrate: bool = True):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"expected one of {ROUTER_POLICIES}")
        # membership is LIVE: the simulator owns (and mutates) this list as
        # replicas join and leave, so keep the caller's list object instead
        # of snapshotting it
        self.replicas = replicas if isinstance(replicas, list) \
            else list(replicas)
        self.policy = policy
        self.steal_queue_depth = steal_queue_depth
        self.steal_batch = steal_batch
        self.migrate = migrate     # ship parked prefixes on steal
        self._rng = np.random.default_rng(seed)
        self._rr = 0
        self.stats = RouterStats()
        self._block_size = self.replicas[0].engine.bm.block_size
        # observability taps (repro.obs sets these): called per placement /
        # per stolen request with the engine-clock timestamp of the move
        self.on_dispatch = None   # (req, replica_id, t)
        self.on_steal = None      # (req, from_id, to_id, t)

    # ---------------------------------------------------------- membership
    def routable(self) -> list:
        """Replicas that may take new work (UP/DEGRADED)."""
        return [r for r in self.replicas if r.routable]

    # ------------------------------------------------------------- dispatch
    def dispatch(self, req: Request) -> Replica:
        if not self.routable():
            raise RuntimeError("no routable replica in the fleet "
                               "(all JOINING/DRAINING/DOWN)")
        if req.is_online:
            rep = self._place_online(req)
            self.stats.online_dispatched += 1
            self.stats.per_replica_online[rep.id] = \
                self.stats.per_replica_online.get(rep.id, 0) + 1
        else:
            rep = self._place_offline(req)
            self.stats.offline_dispatched += 1
            self.stats.per_replica_offline[rep.id] = \
                self.stats.per_replica_offline.get(rep.id, 0) + 1
        rep.submit(req)
        if self.on_dispatch is not None:
            self.on_dispatch(req, rep.id, rep.engine.now)
        return rep

    def _place_online(self, req: Request) -> Replica:
        return min(self.routable(),
                   key=lambda r: (r.predicted_added_latency(req), r.id))

    def _place_offline(self, req: Request) -> Replica:
        live = self.routable()
        if self.policy == "round_robin":
            rep = live[self._rr % len(live)]
            self._rr += 1
            return rep
        if self.policy == "random":
            return live[int(self._rng.integers(len(live)))]
        group = first_block_hash(req, self._block_size)
        # the affinity term sees pooled/in-flight peers, the device-cached
        # prefix, AND the host swap tier: a replica whose device cache was
        # flushed by a burst but whose host tier still parks the document
        # keeps attracting its group (restore over PCIe beats recompute).
        # The hash chain is replica-independent: compute it once per
        # dispatch, probe residency per replica.
        chain = (prefix_chain(req.full_tokens, self._block_size)
                 if group is not None else None)
        scored = [(rep.affinity(group, req, chain), rep)
                  for rep in live]
        best_aff = max(aff for aff, _ in scored)
        if best_aff > 0:
            self.stats.affinity_hits += 1
            # family-aware cost tie-break: at equal affinity and backlog,
            # prefer the replica whose parked prefix is cheapest to restore
            # (a state-family snapshot is one fixed upload; paged KV pays
            # per token — equal block counts are not equal link traffic)
            return min((rep for aff, rep in scored if aff == best_aff),
                       key=lambda r: (r.offline_backlog(),
                                      r.host_prefix_bytes(req, chain), r.id))
        # unseen group: open its home on the least-backlogged replica
        return min(live, key=lambda r: (r.offline_backlog(), r.id))

    # ------------------------------------------------------------ migration
    def _group_left_behind(self, rep: Replica, req: Request) -> bool:
        """Does ``rep`` still hold pooled / in-flight members of ``req``'s
        document group? If so its cached prefix must stay home."""
        group = first_block_hash(req, self._block_size)
        if group is None:
            return False
        eng = rep.engine
        if eng.pool.group_count(group) > 0:
            return True
        bs = self._block_size
        for r in eng.pending:
            if not r.is_online and first_block_hash(r, bs) == group:
                return True
        for r in eng.scheduler.running:
            if not r.is_online and first_block_hash(r, bs) == group:
                return True
        return False

    def migrate_prefix(self, frm: Replica, to: Replica, req: Request) -> int:
        """Ship ``req``'s parked prefix from ``frm`` to ``to`` over the
        inter-node fabric: the source exports the leading cached blocks
        (host tier or idle device copies) and the destination lands them in
        its host tier, where the ordinary swap-in path restores them instead
        of recomputing the prefix. The destination engine is charged
        ``migrate_time`` on its next iteration. Returns fabric bytes
        admitted; 0 when the destination has no host tier (nothing is
        exported, so nothing is lost)."""
        if to.engine.bm.host is None:
            return 0
        hbs, _ = frm.engine.export_prefix(req.full_tokens)
        if not hbs:
            return 0
        admitted = to.engine.import_prefix(hbs)
        self.stats.migrations += 1
        self.stats.migrated_blocks += len(hbs)
        self.stats.migrated_bytes += admitted
        return admitted

    # ------------------------------------------------------------- stealing
    def rebalance(self) -> int:
        """Shed pooled offline work from replicas whose online queue has
        spiked to calm replicas. Each stolen request is re-placed by host-
        tier-aware affinity — stealing moves work *toward* parked KV (a calm
        replica whose swap tier already holds the document's prefix wins
        over the merely least-loaded one), falling back to the calmest
        replica for groups nobody holds. When a steal empties a group at the
        source, the group's parked prefix is migrated to the target over the
        fabric (``migrate=True``) so the stolen work restores instead of
        recomputing. Only routable replicas participate. Returns requests
        moved."""
        moved_total = 0
        for rep in self.routable():
            if rep.online_queue_depth() < self.steal_queue_depth:
                continue
            if rep.offline_backlog() == 0:
                continue
            targets = [o for o in self.routable() if o is not rep
                       and o.online_queue_depth() < self.steal_queue_depth]
            if not targets:
                continue
            moved = rep.steal_offline(self.steal_batch)
            if not moved:
                continue
            calmest = min(targets, key=lambda o: (o.online_queue_depth(),
                                                  o.offline_backlog(), o.id))
            for req in moved:
                group = first_block_hash(req, self._block_size)
                chain = (prefix_chain(req.full_tokens, self._block_size)
                         if group is not None else None)
                scored = [(o.affinity(group, req, chain), o)
                          for o in targets]
                best_aff = max(aff for aff, _ in scored)
                if best_aff > 0:
                    target = min((o for aff, o in scored if aff == best_aff),
                                 key=lambda o: (o.online_queue_depth(),
                                                o.offline_backlog(),
                                                o.host_prefix_bytes(req,
                                                                    chain),
                                                o.id))
                    self.stats.steal_affinity_hits += 1
                else:
                    target = calmest
                target.submit(req)
                target.stolen_in += 1
                if self.migrate and target is not rep \
                        and not self._group_left_behind(rep, req):
                    self.migrate_prefix(rep, target, req)
                if self.on_steal is not None:
                    self.on_steal(req, rep.id, target.id, target.engine.now)
            self.stats.steals += 1
            self.stats.stolen_requests += len(moved)
            moved_total += len(moved)
        return moved_total
