"""Cluster-scale co-serving: multi-replica router, prefix-affinity offline
dispatch with work stealing, shared-virtual-clock fleet simulation with
dynamic membership + chaos injection, predictive autoscaling, and fleet
capacity planning (§5.4 extended to N replicas)."""
from repro.cluster.controller import FleetController
from repro.cluster.planner import FleetPlanner, FleetReport
from repro.cluster.replica import (Replica, ReplicaLoad, ReplicaState,
                                   first_block_hash)
from repro.cluster.router import ROUTER_POLICIES, Router, RouterStats
from repro.cluster.simulator import (ChaosConfig, ClusterSimulator,
                                     ClusterStats, KillRecord)

__all__ = [
    "ChaosConfig", "ClusterSimulator", "ClusterStats", "FleetController",
    "FleetPlanner", "FleetReport", "KillRecord", "ROUTER_POLICIES",
    "Replica", "ReplicaLoad", "ReplicaState", "Router", "RouterStats",
    "first_block_hash",
]
