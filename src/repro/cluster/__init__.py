"""Cluster-scale co-serving: multi-replica router, prefix-affinity offline
dispatch with work stealing, shared-virtual-clock fleet simulation, and
fleet capacity planning (§5.4 extended to N replicas)."""
from repro.cluster.planner import FleetPlanner, FleetReport
from repro.cluster.replica import Replica, ReplicaLoad, first_block_hash
from repro.cluster.router import ROUTER_POLICIES, Router, RouterStats
from repro.cluster.simulator import ClusterSimulator, ClusterStats

__all__ = [
    "ClusterSimulator", "ClusterStats", "FleetPlanner", "FleetReport",
    "ROUTER_POLICIES", "Replica", "ReplicaLoad", "Router", "RouterStats",
    "first_block_hash",
]
