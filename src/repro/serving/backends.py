"""Backend adapters: one stepping/intake/abort surface over a single
``EchoEngine`` or a ``ClusterSimulator``.

The facade never touches engine internals directly — everything it needs
(intake, one-event stepping, cancellation, load signals for admission,
legacy batch runs) goes through this protocol, so a service drives a
single-GPU engine and an N-replica cluster identically. ``run_legacy``
delegates to the backend's own ``run`` loop, guaranteeing the ``drive``
compatibility path reproduces the exact trace-benchmark numbers.
"""
from __future__ import annotations

from typing import List, Optional

from repro.cluster.replica import ReplicaState
from repro.cluster.simulator import ClusterSimulator
from repro.core.engine import MAX_STALLS, EchoEngine, EngineListener
from repro.core.request import Request


class EngineBackend:
    """Single-engine backend."""

    default_max_iters = 10_000         # EchoEngine.run's default

    def __init__(self, engine: EchoEngine):
        self.engine = engine
        self._stalls = 0

    # ------------------------------------------------------------- surface
    def engines(self) -> List[EchoEngine]:
        return [self.engine]

    def attach(self, listener: EngineListener) -> None:
        if listener not in self.engine.listeners:
            self.engine.listeners.append(listener)

    def now(self) -> float:
        return self.engine.now

    def submit(self, req: Request) -> None:
        self.engine.submit(req)
        self._stalls = 0               # new work can unblock a stalled engine

    def abort(self, req: Request) -> bool:
        return self.engine.abort(req)

    def has_work(self) -> bool:
        return self.engine.has_work()

    def step(self, until_time: Optional[float] = None) -> bool:
        """One engine iteration with EchoEngine.run's stall semantics:
        returns False once nothing is left (or the backlog is provably
        unschedulable — the deadlock guard)."""
        eng = self.engine
        if until_time is not None and eng.now >= until_time:
            return False
        if self._stalls > MAX_STALLS or not self.has_work():
            return False
        rec = eng.step()
        if rec is None and not eng.pending:
            self._stalls += 1
        else:
            self._stalls = 0
        return True

    def run_legacy(self, max_iters: Optional[int] = None,
                   until_time: Optional[float] = None):
        return self.engine.run(max_iters or self.default_max_iters,
                               until_time=until_time)

    def flush(self) -> None:
        """Land in-flight swap staging (graceful-drain hook)."""
        self.engine.flush_swaps()

    def stats(self):
        return self.engine.stats

    # --------------------------------------------------------- load signals
    # (delegated to the engine — the same accounting cluster replicas use,
    # so engine and cluster admission caps compare)
    def online_queue_depth(self) -> int:
        return self.engine.online_queue_depth()

    def offline_backlog(self) -> int:
        return self.engine.offline_backlog()

    def predicted_ttft(self, req: Request) -> float:
        return self.engine.predicted_first_token_latency(req)


class ClusterBackend:
    """N-replica backend: intake goes through the cluster's arrival heap so
    the router places it; stepping advances one cluster event."""

    default_max_iters = 200_000        # ClusterSimulator.run's default

    def __init__(self, sim: ClusterSimulator):
        self.sim = sim

    # ------------------------------------------------------------- surface
    def engines(self) -> List[EchoEngine]:
        return [rep.engine for rep in self.sim.replicas]

    def attach(self, listener: EngineListener) -> None:
        for eng in self.engines():
            if listener not in eng.listeners:
                eng.listeners.append(listener)

    def now(self) -> float:
        """The cluster's event frontier: the clock of the next replica to
        step. Idle replicas must not hold it back — the legacy loop
        dispatches an arrival once ``t_arr <= min(busy replica clocks)``,
        and the service's held-arrival release mirrors that condition. With
        nothing busy, time has effectively advanced to the latest clock."""
        busy = [rep.engine.now for rep in self.sim.replicas
                if rep.state != ReplicaState.DOWN and rep.has_work()]
        if busy:
            return min(busy)
        return max((eng.now for eng in self.engines()), default=0.0)

    def submit(self, req: Request) -> None:
        self.sim.submit(req)

    def abort(self, req: Request) -> bool:
        return self.sim.abort(req)

    def has_work(self) -> bool:
        return bool(self.sim._pending) or \
            any(rep.has_work() for rep in self.sim.replicas)

    def step(self, until_time: Optional[float] = None) -> bool:
        return self.sim.step_event(until_time)

    def run_legacy(self, max_iters: Optional[int] = None,
                   until_time: Optional[float] = None):
        return self.sim.run(max_iters or self.default_max_iters,
                            until_time=until_time)

    def flush(self) -> None:
        for eng in self.engines():
            eng.flush_swaps()

    def stats(self):
        return self.sim.stats()

    # --------------------------------------------------------- load signals
    def online_queue_depth(self) -> int:
        n = sum(1 for _, _, r in self.sim._pending if r.is_online)
        n += sum(rep.online_queue_depth() for rep in self.sim.replicas)
        return n

    def offline_backlog(self) -> int:
        n = sum(1 for _, _, r in self.sim._pending if not r.is_online)
        n += sum(rep.offline_backlog() for rep in self.sim.replicas)
        return n

    def predicted_ttft(self, req: Request) -> float:
        """Best placement among replicas the router would actually use —
        JOINING/DRAINING/DOWN members must not make admission optimistic.
        With no routable replica (mid-failover), infinity: shed/queue."""
        live = self.sim.router.routable()
        if not live:
            return float("inf")
        return min(rep.predicted_added_latency(req) for rep in live)


def make_backend(target):
    """Coerce an ``EchoEngine``, ``ClusterSimulator``, or ready-made backend
    into the backend protocol."""
    if isinstance(target, EchoEngine):
        return EngineBackend(target)
    if isinstance(target, ClusterSimulator):
        return ClusterBackend(target)
    if hasattr(target, "step") and hasattr(target, "submit") \
            and hasattr(target, "engines"):
        return target
    raise TypeError(f"cannot build a serving backend from {type(target)!r}")
