"""Service event bus + live metrics.

The bus turns engine-level lifecycle hooks into subscriber callbacks keyed
by event name — the push-based replacement for scraping ``EngineStats``
after a run. ``LiveMetrics`` is the canonical subscriber: it maintains the
paper's headline metrics (SLO attainment, completed offline tokens,
finished counts) incrementally from events, matching the post-hoc
``EngineStats`` accounting on the decidable-request rule.
"""
from __future__ import annotations

import logging
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import Histogram, LATENCY_BUCKETS
from repro.serving.handle import RequestHandle, TokenEvent

logger = logging.getLogger(__name__)


@dataclass(frozen=True)
class SwapEvent:
    """KV traffic between the device cache and the host tier. Swap-ins
    belong to the request whose prefix was restored; swap-outs are
    hash-level (the evicted block may serve many future requests), so
    ``handle`` is None for them."""
    tokens: int
    t: float
    handle: Optional[RequestHandle] = None


@dataclass(frozen=True)
class OverlapEvent:
    """One iteration's swap/compute overlap accounting: ``transfer`` seconds
    of PCIe traffic were put on the copy stream, of which only ``exposed``
    seconds reached the clock (the tail compute could not hide)."""
    transfer: float
    exposed: float
    t: float

    @property
    def hidden(self) -> float:
        return max(self.transfer - self.exposed, 0.0)


class EventBus:
    """Named-event subscriptions. ``token``/``first_token`` callbacks get a
    ``TokenEvent``; ``finish``/``preempt``/``abort``/``shed``/``requeue``
    callbacks get the ``RequestHandle``; ``swap_in``/``swap_out`` get a
    ``SwapEvent``. Callbacks run synchronously at iteration end.

    Emission is serialized under one re-entrant lock: the real-time layer
    drives ``engine.step`` on a worker thread while the event loop thread
    sheds/aborts through the same service, so two threads can reach
    ``emit`` concurrently. The lock makes every subscriber — LiveMetrics
    above all — single-threaded by construction (callbacks may re-emit;
    hence re-entrant)."""

    EVENTS = ("token", "first_token", "finish", "preempt", "abort", "shed",
              "requeue", "swap_in", "swap_out", "swap_overlap")

    def __init__(self):
        self._subs: Dict[str, List[Callable]] = {e: [] for e in self.EVENTS}
        # a raising subscriber must not take the serving loop down with it:
        # emit() swallows the exception, counts it here, and keeps going
        self.dropped_callbacks = 0
        self._warned: set = set()
        self._lock = threading.RLock()

    def subscribe(self, event: str, cb: Callable) -> Callable:
        if event not in self._subs:
            raise ValueError(f"unknown event {event!r}; "
                             f"expected one of {self.EVENTS}")
        with self._lock:
            self._subs[event].append(cb)
        return cb                      # decorator-friendly

    def unsubscribe(self, event: str, cb: Callable) -> None:
        with self._lock:
            self._subs[event].remove(cb)

    # convenience decorators / registrars --------------------------------
    def on_token(self, cb: Callable[[TokenEvent], None]) -> Callable:
        return self.subscribe("token", cb)

    def on_first_token(self, cb: Callable[[TokenEvent], None]) -> Callable:
        return self.subscribe("first_token", cb)

    def on_finish(self, cb: Callable[[RequestHandle], None]) -> Callable:
        return self.subscribe("finish", cb)

    def on_preempt(self, cb: Callable[[RequestHandle], None]) -> Callable:
        return self.subscribe("preempt", cb)

    def on_abort(self, cb: Callable[[RequestHandle], None]) -> Callable:
        return self.subscribe("abort", cb)

    def on_shed(self, cb: Callable[[RequestHandle], None]) -> Callable:
        return self.subscribe("shed", cb)

    def on_requeue(self, cb: Callable[[RequestHandle], None]) -> Callable:
        """Deferred offline work re-admitted from the overflow queue."""
        return self.subscribe("requeue", cb)

    def on_swap_in(self, cb: Callable[[SwapEvent], None]) -> Callable:
        return self.subscribe("swap_in", cb)

    def on_swap_out(self, cb: Callable[[SwapEvent], None]) -> Callable:
        return self.subscribe("swap_out", cb)

    def on_swap_overlap(self, cb: Callable[[OverlapEvent], None]) -> Callable:
        """Per-iteration swap/compute overlap accounting (transfer vs the
        exposed tail that actually reached the clock)."""
        return self.subscribe("swap_overlap", cb)

    # emission ------------------------------------------------------------
    def emit(self, event: str, payload) -> None:
        with self._lock:
            for cb in list(self._subs[event]):
                try:
                    cb(payload)
                except Exception:
                    self.dropped_callbacks += 1
                    key = (event, cb)
                    if key not in self._warned:   # log once per (event, cb)
                        self._warned.add(key)
                        logger.warning("subscriber %r raised on %r; "
                                       "suppressing further warnings for "
                                       "this pair", cb, event, exc_info=True)


class LiveMetrics:
    """Event-driven serving metrics, updated as tokens stream.

    Attainment follows ``EngineStats.slo_attainment`` exactly: only
    *decidable* finished online requests enter the denominator (ttft needs a
    first token; tpot needs >= 2 output tokens), so at end of run the live
    numbers equal the post-hoc scrape.

    Thread-safety: every handler runs inside ``EventBus.emit``'s lock, so
    the counters stay exact even when the off-thread step loop and the
    event-loop thread emit concurrently — no locking needed here."""

    def __init__(self, bus: EventBus):
        self.online_tokens = 0
        self.offline_tokens = 0
        self.first_tokens = 0
        self.finished_online = 0
        self.finished_offline = 0
        self.aborted = 0
        self.shed = 0
        self.preemptions = 0
        self.requeued = 0                   # deferred -> queued transitions
        self.swap_ins = 0
        self.swap_outs = 0
        self.swapped_in_tokens = 0          # recompute avoided via host KV
        self.swapped_out_tokens = 0
        self.swap_transfer_time = 0.0       # PCIe seconds on the copy stream
        self.swap_exposed_time = 0.0        # the tail NOT hidden by compute
        self.completed_offline_tokens = 0   # prompt + generated, on finish
        self.last_offline_finish_t: Optional[float] = None
        self._slo = {"ttft": [0, 0], "tpot": [0, 0]}    # kind -> [ok, n]
        # pre-bucketed latency distributions (p50/p90/p99 queries); recorded
        # on finish for online requests, matching the attainment denominator
        self.hists: Dict[str, Histogram] = {
            "ttft": Histogram("ttft_seconds", "time to first token",
                              buckets=LATENCY_BUCKETS),
            "tpot": Histogram("tpot_seconds", "time per output token",
                              buckets=LATENCY_BUCKETS),
            "queue_delay": Histogram("queue_delay_seconds",
                                     "arrival to first batch admission",
                                     buckets=LATENCY_BUCKETS),
        }
        bus.on_token(self._token)
        bus.on_first_token(self._first_token)
        bus.on_finish(self._finish)
        bus.on_preempt(self._preempt)
        bus.on_abort(self._abort)
        bus.on_shed(self._shed_cb)
        bus.on_requeue(self._requeue)
        bus.on_swap_in(self._swap_in)
        bus.on_swap_out(self._swap_out)
        bus.on_swap_overlap(self._swap_overlap)

    # ------------------------------------------------------------- handlers
    def _token(self, ev: TokenEvent) -> None:
        if ev.handle.request.is_online:
            self.online_tokens += 1
        else:
            self.offline_tokens += 1

    def _first_token(self, ev: TokenEvent) -> None:
        self.first_tokens += 1

    def _finish(self, handle: RequestHandle) -> None:
        req = handle.request
        qd = req.queue_delay()
        if qd is not None:
            self.hists["queue_delay"].observe(qd)
        if req.is_online:
            self.finished_online += 1
            ttft, tpot = req.ttft(), req.tpot()
            if ttft is not None:
                self.hists["ttft"].observe(ttft)
            if tpot is not None:
                self.hists["tpot"].observe(tpot)
            if req.slo is not None:
                if ttft is not None:
                    self._slo["ttft"][1] += 1
                    self._slo["ttft"][0] += ttft <= req.slo.ttft
                if tpot is not None:
                    self._slo["tpot"][1] += 1
                    self._slo["tpot"][0] += tpot <= req.slo.tpot
        else:
            self.finished_offline += 1
            self.completed_offline_tokens += req.prompt_len + req.n_output
            self.last_offline_finish_t = req.finish_time

    def _preempt(self, handle: RequestHandle) -> None:
        self.preemptions += 1

    def _abort(self, handle: RequestHandle) -> None:
        self.aborted += 1

    def _shed_cb(self, handle: RequestHandle) -> None:
        self.shed += 1

    def _requeue(self, handle: RequestHandle) -> None:
        self.requeued += 1

    def _swap_in(self, ev: SwapEvent) -> None:
        self.swap_ins += 1
        self.swapped_in_tokens += ev.tokens

    def _swap_out(self, ev: SwapEvent) -> None:
        self.swap_outs += 1
        self.swapped_out_tokens += ev.tokens

    def _swap_overlap(self, ev: "OverlapEvent") -> None:
        self.swap_transfer_time += ev.transfer
        self.swap_exposed_time += ev.exposed

    # ------------------------------------------------------------- queries
    def swap_hidden_frac(self) -> float:
        """Fraction of swap traffic the overlap hid (0.0 serial/swap-free),
        matching ``EngineStats.swap_hidden_frac`` at end of run."""
        if self.swap_transfer_time <= 0.0:
            return 0.0
        return max(1.0 - self.swap_exposed_time / self.swap_transfer_time,
                   0.0)

    def slo_attainment(self, kind: str = "ttft") -> float:
        ok, n = self._slo[kind]
        return ok / n if n else 1.0

    def offline_throughput(self) -> float:
        """Completed offline work per second of offline activity, from
        events alone (finish-time makespan)."""
        if self.last_offline_finish_t is None:
            return 0.0
        return self.completed_offline_tokens / (self.last_offline_finish_t
                                                + 1e-9)

    def percentile(self, metric: str, q: float) -> Optional[float]:
        """Bucket-interpolated quantile of ``ttft`` / ``tpot`` /
        ``queue_delay``; None before the first observation."""
        return self.hists[metric].percentile(q)

    def percentiles(self, qs=(0.5, 0.9, 0.99)) -> Dict[str, Dict[str, float]]:
        """{"ttft": {"p50": ..., "p90": ..., "p99": ...}, ...} — metrics
        with no observations yet are omitted."""
        out: Dict[str, Dict[str, float]] = {}
        for name, h in self.hists.items():
            vals = {f"p{int(q * 100)}": h.percentile(q) for q in qs}
            if all(v is not None for v in vals.values()):
                out[name] = vals
        return out
