"""Online serving facade: request handles, streaming, cancellation, and
admission control over engine and cluster backends (ISSUE 3)."""
from repro.serving.admission import AdmissionConfig, AdmissionController
from repro.serving.backends import ClusterBackend, EngineBackend, make_backend
from repro.serving.events import (EventBus, LiveMetrics, OverlapEvent,
                                  SwapEvent)
from repro.serving.handle import (TERMINAL_STATUSES, HandleStatus,
                                  RequestHandle, RequestResult, TokenEvent)
from repro.serving.service import EchoService

__all__ = [
    "AdmissionConfig", "AdmissionController", "ClusterBackend", "EchoService",
    "EngineBackend", "EventBus", "HandleStatus", "LiveMetrics",
    "OverlapEvent", "RequestHandle", "RequestResult", "SwapEvent",
    "TERMINAL_STATUSES",
    "TokenEvent", "make_backend",
]
