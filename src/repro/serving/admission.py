"""Admission control: backpressure at the service front door.

Today's engine keeps an unbounded ``pending`` list — a traffic spike simply
queues forever and every SLO is missed late instead of shed early. The
controller applies three gates at ``submit`` time (HyGen/ConServe-style
elastic co-location, §4):

  * bounded online queue   — over ``max_online_queue`` waiting online
                             requests, new online arrivals are SHED;
  * SLO-feasibility shed   — if the TimeModel predicts the request cannot
                             make its TTFT deadline even if admitted now
                             (predicted first-token latency > ttft *
                             ``slo_shed_factor``), admit nobody we will
                             certainly fail: SHED on arrival;
  * offline pool soft cap  — offline work beyond ``offline_pool_cap``
                             backlog is *deferred* (held in a service-side
                             overflow queue, status QUEUED) and fed to the
                             backend as the pool drains — backpressure
                             without data loss, since offline tasks have no
                             deadline.

All gates default to off; a gate-less controller admits everything, which
is exactly the legacy ``submit_all`` behaviour the ``drive`` compatibility
path relies on.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional

from repro.serving.handle import RequestHandle

ADMIT, SHED, DEFER = "admit", "shed", "defer"


@dataclass(frozen=True)
class AdmissionConfig:
    max_online_queue: Optional[int] = None   # bounded online queue (None=∞)
    slo_shed_factor: Optional[float] = None  # shed if pred TTFT > f * slo.ttft
    offline_pool_cap: Optional[int] = None   # soft cap on offline backlog

    @property
    def active(self) -> bool:
        return (self.max_online_queue is not None
                or self.slo_shed_factor is not None
                or self.offline_pool_cap is not None)


class AdmissionController:
    """Applies an ``AdmissionConfig`` against a service backend."""

    def __init__(self, config: AdmissionConfig):
        self.config = config
        self.deferred: Deque[RequestHandle] = deque()
        self._deferred_rids: set = set()   # live membership, O(1) cancel
        self._tombstones: set = set()      # rids cancelled while deferred
        self.shed_online = 0
        self.deferred_total = 0
        self.requeued_total = 0

    # ------------------------------------------------------------- verdict
    def verdict(self, backend, handle: RequestHandle) -> str:
        c = self.config
        req = handle.request
        if req.is_online:
            if c.max_online_queue is not None and \
                    backend.online_queue_depth() >= c.max_online_queue:
                self.shed_online += 1
                return SHED
            if c.slo_shed_factor is not None and req.slo is not None:
                pred = backend.predicted_ttft(req)
                if pred > req.slo.ttft * c.slo_shed_factor:
                    self.shed_online += 1
                    return SHED
            return ADMIT
        if c.offline_pool_cap is not None and \
                backend.offline_backlog() >= c.offline_pool_cap:
            self.deferred.append(handle)
            self._deferred_rids.add(handle.rid)
            self.deferred_total += 1
            return DEFER
        return ADMIT

    # ------------------------------------------------------------- pumping
    def pump(self, backend, events=None) -> int:
        """Feed deferred offline work into the backend while its backlog is
        under the soft cap. Called by the service before every step.

        Each resubmission re-runs the admission verdict (the gate may have
        tightened, or the handle may have gone terminal while deferred —
        blindly submitting an aborted handle would resurrect it) and emits a
        ``requeue`` event so LiveMetrics sees every deferred->queued
        transition. Cancelled handles are tombstoned by ``cancel`` and
        dropped lazily here, keeping cancellation O(1)."""
        fed = 0
        while self.deferred:
            handle = self.deferred.popleft()
            if handle.rid in self._tombstones:       # cancelled while queued
                self._tombstones.discard(handle.rid)
                continue
            self._deferred_rids.discard(handle.rid)
            if handle.done:                          # aborted/terminal: drop
                handle._deferred = False
                continue
            verdict = self.verdict(backend, handle)
            if verdict == DEFER:
                # still capped: verdict() re-appended at the TAIL; restore
                # the handle to the head so a saturated cap does not rotate
                # the queue (deferred work must drain FIFO)
                self.deferred.pop()
                self.deferred.appendleft(handle)
                self.deferred_total -= 1             # not a new deferral
                break
            handle._deferred = False
            if verdict == ADMIT:
                backend.submit(handle.request)
                self.requeued_total += 1
                if events is not None:
                    events.emit("requeue", handle)
                fed += 1
            else:                                    # SHED (gate tightened)
                handle._shed = True
                if events is not None:
                    events.emit("shed", handle)
        return fed

    def cancel(self, handle: RequestHandle) -> bool:
        """Drop a still-deferred handle from the overflow queue — O(1) via a
        tombstone; the deque entry is skipped on the next ``pump``."""
        if handle.rid not in self._deferred_rids:
            return False
        self._deferred_rids.discard(handle.rid)
        self._tombstones.add(handle.rid)
        handle._deferred = False
        return True
