"""EchoService: the unified request-lifecycle facade (one front-end API for
engine and cluster backends).

    service = EchoService(engine_or_cluster,
                          admission=AdmissionConfig(max_online_queue=32))
    h = service.submit(prompt, task_type="online", max_new_tokens=16,
                       slo=SLO(1.0, 0.1))
    for ev in h.tokens():          # streams while the service schedules
        ...
    h.abort()                      # or cancel mid-flight: zero leaked blocks

Three layers below this facade stay unchanged: ``EchoEngine.step()`` is the
low-level iteration primitive, ``ClusterSimulator.step_event()`` its
fleet-wide analogue, and the scheduler/KV manager are untouched. The
service adds what an *online* system needs on top: handles with streaming
and cancellation, an event bus for live metrics, and admission backpressure
instead of an unbounded pending list. ``drive(workload)`` is the
compatibility driver: with admission off it delegates to the backend's own
``run`` loop, so trace benchmarks keep their exact numbers.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core.engine import EchoEngine, EngineListener
from repro.core.request import SLO, Request, TaskType
from repro.serving.admission import (ADMIT, DEFER, SHED, AdmissionConfig,
                                     AdmissionController)
from repro.serving.backends import make_backend
from repro.serving.events import (EventBus, LiveMetrics, OverlapEvent,
                                  SwapEvent)
from repro.serving.handle import RequestHandle, TokenEvent


class _ServiceListener(EngineListener):
    """Bridges engine-level hooks onto the service's handles and bus."""

    def __init__(self, service: "EchoService"):
        self.service = service

    def on_token(self, req: Request, tok: int, t: float) -> None:
        self.service._on_token(req, tok, t)

    def on_preempt(self, req: Request, t: float) -> None:
        self.service._on_preempt(req, t)

    def on_finish(self, req: Request, t: float) -> None:
        self.service._on_finish(req, t)

    def on_swap_in(self, req: Request, n_tokens: int, t: float) -> None:
        self.service._on_swap_in(req, n_tokens, t)

    def on_swap_out(self, n_tokens: int, t: float) -> None:
        self.service._on_swap_out(n_tokens, t)

    def on_swap_overlap(self, transfer_s: float, exposed_s: float,
                        t: float) -> None:
        self.service._on_swap_overlap(transfer_s, exposed_s, t)


class EchoService:
    """Unified request-lifecycle API over an ``EchoEngine`` or a
    ``ClusterSimulator`` (routing stays behind the facade)."""

    def __init__(self, backend, *,
                 admission: Union[AdmissionConfig, AdmissionController,
                                  None] = None):
        self.backend = make_backend(backend)
        if isinstance(admission, AdmissionConfig):
            admission = AdmissionController(admission)
        self.admission: Optional[AdmissionController] = admission
        self.events = EventBus()
        self.live = LiveMetrics(self.events)
        self.handles: Dict[int, RequestHandle] = {}      # rid -> LIVE handles
        # (terminal handles are evicted; callers keep the ones they hold)
        # future arrivals held at the front door when admission is on: the
        # verdict must be taken when the clock *reaches* the arrival, not at
        # submit time — judging a whole replayed trace against the t=0 queue
        # would shed almost everything
        self._held: List[Tuple[float, int, RequestHandle]] = []
        self.backend.attach(_ServiceListener(self))

    # ------------------------------------------------------------- sugar
    @property
    def engine(self) -> EchoEngine:
        """The single engine of an engine backend (first replica's engine
        on a cluster) — convenience for metrics introspection."""
        return self.backend.engines()[0]

    @property
    def now(self) -> float:
        return self.backend.now()

    # ------------------------------------------------------------- intake
    def submit(self, prompt: Sequence[int], *,
               task_type: Union[TaskType, str] = TaskType.ONLINE,
               max_new_tokens: int = 16,
               slo: Optional[SLO] = None,
               arrival_time: Optional[float] = None) -> RequestHandle:
        """Build and submit one request; returns its live handle.
        ``arrival_time`` defaults to the backend's current clock (live
        feeding); pass an explicit time to replay a trace."""
        if isinstance(task_type, str):
            task_type = TaskType(task_type)
        req = Request(prompt=tuple(prompt), max_new_tokens=max_new_tokens,
                      task_type=task_type,
                      arrival_time=(self.backend.now()
                                    if arrival_time is None else arrival_time),
                      slo=slo)
        return self.submit_request(req)

    def submit_request(self, req: Request) -> RequestHandle:
        """Submit a pre-built ``Request`` through admission control. With
        admission on, a request whose ``arrival_time`` lies in the future is
        held at the front door and judged when the clock reaches it."""
        handle = RequestHandle(self, req)
        self.handles[req.rid] = handle
        if self._admission_active() and \
                req.arrival_time > self.backend.now():
            handle._deferred = True
            heapq.heappush(self._held, (req.arrival_time, req.rid, handle))
            return handle
        self._admit(handle)
        return handle

    def _admission_active(self) -> bool:
        return self.admission is not None and self.admission.config.active

    def _admit(self, handle: RequestHandle) -> None:
        """Take the admission verdict now and route accordingly."""
        verdict = (self.admission.verdict(self.backend, handle)
                   if self.admission is not None else ADMIT)
        if verdict == SHED:
            handle._shed = True
            self.events.emit("shed", handle)
            self.handles.pop(handle.rid, None)       # terminal: release
        elif verdict == DEFER:
            handle._deferred = True          # controller holds it; fed later
        else:
            self.backend.submit(handle.request)

    def _release_arrivals(self, force_one: bool = False) -> None:
        """Move held arrivals whose time has come through admission. With
        ``force_one`` the earliest held arrival is released even though the
        clock has not reached it yet — used when the backend is otherwise
        idle, so its own idle-advance can jump to the arrival."""
        now = self.backend.now()
        while self._held and (self._held[0][0] <= now or force_one):
            _, _, handle = heapq.heappop(self._held)
            handle._deferred = False
            self._admit(handle)
            force_one = False

    # ------------------------------------------------------------- control
    def abort(self, handle: RequestHandle) -> bool:
        """Cancel a request mid-flight. Frees its KV blocks
        (``BlockManager.free_request``), drops its radix-pool pins, removes
        it from scheduler queues, and fires ``on_abort``."""
        if handle.done:
            return False
        if not ((handle._deferred and (self._cancel_held(handle)
                                       or (self.admission is not None
                                           and self.admission.cancel(handle))))
                or self.backend.abort(handle.request)):
            return False
        handle._aborted = True
        self.events.emit("abort", handle)
        self.handles.pop(handle.rid, None)           # terminal: release
        return True

    def _cancel_held(self, handle: RequestHandle) -> bool:
        for i, (_, _, h) in enumerate(self._held):
            if h is handle:
                self._held.pop(i)
                heapq.heapify(self._held)
                handle._deferred = False
                return True
        return False

    # ------------------------------------------------------------- driving
    def step(self, until_time: Optional[float] = None) -> bool:
        """Advance the backend by one event (one engine iteration / one
        cluster event), first releasing due held arrivals and feeding
        deferred offline work. Returns False when no further progress is
        possible."""
        if self.admission is not None:
            self._release_arrivals()
            self.admission.pump(self.backend, self.events)
        if self.backend.step(until_time):
            return True
        # backend idle, but future arrivals are still held at the front
        # door: release the earliest so the backend's idle-advance can jump
        # the clock to it. Keep releasing — an arrival may be shed on
        # release (admitting nothing), and later held arrivals must still
        # get their verdict.
        while self._held:
            self._release_arrivals(force_one=True)
            if self.admission is not None:
                self.admission.pump(self.backend, self.events)
            if self.backend.step(until_time):
                return True
        return False

    def run(self, max_iters: Optional[int] = None,
            until_time: Optional[float] = None):
        """Drive until idle (or ``until_time``); returns backend stats."""
        for _ in range(max_iters or self.backend.default_max_iters):
            if not self.step(until_time):
                break
        return self.stats()

    def drive(self, workload: Iterable[Request], *,
              max_iters: Optional[int] = None,
              until_time: Optional[float] = None):
        """Compatibility driver for trace benchmarks: submit a pre-generated
        workload and run it to completion, returning ``EngineStats`` /
        ``ClusterStats`` exactly as the legacy ``submit_all`` + ``run`` path
        did. With no admission gates this delegates to the backend's own
        ``run`` loop, so the numbers are bit-identical; events still flow
        (``service.events``, ``service.live``)."""
        for req in workload:
            self.submit_request(req)
        if self.admission is None or not self.admission.config.active:
            return self.backend.run_legacy(max_iters, until_time)
        return self.run(max_iters, until_time)

    def stats(self):
        return self.backend.stats()

    def pending_frontdoor(self) -> int:
        """Requests held at the front door, not yet visible to the backend:
        future arrivals awaiting their admission verdict plus offline work
        parked in the admission overflow queue. The real-time drain loop
        treats these as outstanding work."""
        n = len(self._held)
        if self.admission is not None:
            n += len(self.admission.deferred)
        return n

    # ------------------------------------------------------------- obs
    def instrument(self, registry=None, tracer=None):
        """Attach the observability layer (``repro.obs``): the bus-level
        metric bridge plus per-engine drift probes into ``registry``
        (created when None), and — given a ``Tracer`` — the lifecycle
        trace tracks. Returns the registry. Imported lazily so the plain
        serving path never loads the obs package."""
        from repro.obs import MetricsRegistry
        from repro.obs.probes import instrument as _instrument
        if registry is None:
            registry = MetricsRegistry()
        _instrument(self, registry, tracer)
        return registry

    # ------------------------------------------------------------- wiring
    def _handle_for(self, req: Request) -> Optional[RequestHandle]:
        return self.handles.get(req.rid)

    def _on_token(self, req: Request, tok: int, t: float) -> None:
        handle = self._handle_for(req)
        if handle is None:
            return                      # foreign request (legacy direct use)
        ev = TokenEvent(handle=handle, token=tok, t=t,
                        index=len(handle.token_events))
        handle.token_events.append(ev)
        self.events.emit("token", ev)
        if ev.first:
            self.events.emit("first_token", ev)

    def _on_preempt(self, req: Request, t: float) -> None:
        handle = self._handle_for(req)
        if handle is not None:
            self.events.emit("preempt", handle)

    def _on_swap_in(self, req: Request, n_tokens: int, t: float) -> None:
        self.events.emit("swap_in", SwapEvent(tokens=n_tokens, t=t,
                                              handle=self._handle_for(req)))

    def _on_swap_out(self, n_tokens: int, t: float) -> None:
        self.events.emit("swap_out", SwapEvent(tokens=n_tokens, t=t))

    def _on_swap_overlap(self, transfer_s: float, exposed_s: float,
                         t: float) -> None:
        self.events.emit("swap_overlap", OverlapEvent(transfer=transfer_s,
                                                      exposed=exposed_s, t=t))

    def _on_finish(self, req: Request, t: float) -> None:
        handle = self._handle_for(req)
        if handle is not None:
            self.events.emit("finish", handle)
            # terminal: drop the service's reference so a long-lived service
            # retains O(live requests), not O(all requests ever). The caller
            # keeps streaming/replaying through the handle it holds.
            self.handles.pop(req.rid, None)
