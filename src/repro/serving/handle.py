"""Request handles: the caller's view of one in-flight request.

A ``RequestHandle`` is returned by ``EchoService.submit`` and is the only
object a front-end needs to hold: it streams token events (``tokens()``),
blocks for the final result (``result()``), reports live lifecycle status
(``status``), and cancels mid-flight (``abort()``). Streaming in this
discrete-event world means the generator *drives* the backend — each
``tokens()`` iteration advances the service until the next token (or a
terminal state) appears, so tokens interleave with scheduling exactly as
they would on a wall-clock server.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterator, List, Optional

from repro.core.request import Request, RequestState

if TYPE_CHECKING:                      # avoid a runtime import cycle
    from repro.serving.service import EchoService


class HandleStatus(enum.Enum):
    QUEUED = "queued"          # admitted; waiting for KV/batch slots
    RUNNING = "running"        # in the active batch (prefilling or decoding)
    PREEMPTED = "preempted"    # evicted mid-flight; will be re-admitted
    FINISHED = "finished"      # all tokens generated
    ABORTED = "aborted"        # cancelled; resources released
    SHED = "shed"              # rejected by admission control


TERMINAL_STATUSES = frozenset(
    (HandleStatus.FINISHED, HandleStatus.ABORTED, HandleStatus.SHED))


@dataclass(frozen=True)
class TokenEvent:
    """One generated token, stamped with the (virtual or wall) clock."""
    handle: "RequestHandle"
    token: int
    t: float                   # service clock at emission (iteration end)
    index: int                 # 0-based output position

    @property
    def first(self) -> bool:
        return self.index == 0


@dataclass
class RequestResult:
    """Terminal summary returned by ``RequestHandle.result()``."""
    tokens: List[int]
    status: HandleStatus
    ttft: Optional[float]
    tpot: Optional[float]
    finish_time: Optional[float]
    n_preemptions: int


class RequestHandle:
    """Live view of one request inside an ``EchoService``."""

    def __init__(self, service: "EchoService", request: Request):
        self._service = service
        self.request = request
        self.token_events: List[TokenEvent] = []
        self._shed = False             # rejected at admission
        self._aborted = False
        self._deferred = False         # held in the admission overflow queue

    # ------------------------------------------------------------- identity
    @property
    def rid(self) -> int:
        return self.request.rid

    def __repr__(self) -> str:
        return (f"RequestHandle(rid={self.rid}, "
                f"status={self.status.value}, "
                f"tokens={len(self.token_events)})")

    # ------------------------------------------------------------- status
    @property
    def status(self) -> HandleStatus:
        if self._shed:
            return HandleStatus.SHED
        req = self.request
        if self._aborted or req.state == RequestState.ABORTED:
            return HandleStatus.ABORTED
        if req.state == RequestState.FINISHED:
            return HandleStatus.FINISHED
        if req.state == RequestState.RUNNING:
            return HandleStatus.RUNNING
        # WAITING: either never started or kicked out mid-flight
        if req.n_preemptions > 0:
            return HandleStatus.PREEMPTED
        return HandleStatus.QUEUED

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    # ------------------------------------------------------------- metrics
    def ttft(self) -> Optional[float]:
        return self.request.ttft()

    def tpot(self) -> Optional[float]:
        return self.request.tpot()

    # ------------------------------------------------------------- stream
    def tokens(self) -> Iterator[TokenEvent]:
        """Incremental token events. Replays what already arrived, then
        *drives the service* one event at a time until this request reaches
        a terminal state (or the backend can make no more progress)."""
        i = 0
        while True:
            while i < len(self.token_events):
                yield self.token_events[i]
                i += 1
            if self.done:
                return
            if not self._service.step():
                return                  # backend drained or stalled

    # ------------------------------------------------------------- result
    def result(self) -> RequestResult:
        """Drive the service until this request is terminal, then summarize.
        Never raises on cancellation — an aborted/shed request reports its
        partial tokens with the matching status."""
        while not self.done and self._service.step():
            pass
        req = self.request
        return RequestResult(tokens=list(req.output_tokens),
                             status=self.status,
                             ttft=req.ttft(), tpot=req.tpot(),
                             finish_time=req.finish_time,
                             n_preemptions=req.n_preemptions)

    # ------------------------------------------------------------- control
    def abort(self) -> bool:
        """Cancel mid-flight: frees KV blocks, drops radix-pool pins, and
        removes the request from scheduler queues. Returns False if the
        request was already terminal."""
        return self._service.abort(self)
