"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

M-RoPE (temporal/height/width sections), dynamic-resolution vision frontend
stubbed: input_specs() supplies precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    vocab_size=152064,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # half-dims; sum == head_dim // 2
    multimodal=True,
    mm_embed_dim=1280,
    long_context="sliding_window",
)
