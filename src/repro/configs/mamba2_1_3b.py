"""Mamba-2 1.3B [arXiv:2405.21060]. Attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    vocab_size=50280,
    d_ff=0,                    # attention-free, no MLP block (SSD block only)
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=64,
    tie_embeddings=True,
    long_context="native",     # O(1) state per token
)
