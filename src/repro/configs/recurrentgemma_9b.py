"""RecurrentGemma-9B [arXiv:2402.19427 Griffin].

Hybrid: repeating (RG-LRU, RG-LRU, local-attention) blocks — 1:2
attention:recurrence — 38 layers total (12 full blocks + 2 RG-LRU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    vocab_size=256000,
    num_heads=16,
    num_kv_heads=1,           # local MQA
    head_dim=256,
    d_ff=12288,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048,
    lru_width=4096,
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context="native",    # RG-LRU state + bounded local-attn window
)
