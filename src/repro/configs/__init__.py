"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-4b": "qwen3_4b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "yi-9b": "yi_9b",
    "musicgen-medium": "musicgen_medium",
    "granite-34b": "granite_34b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "get_shape",
]
