"""Configuration dataclasses for the repro framework.

ModelConfig describes one architecture from the assigned pool; InputShape
describes one of the four assigned workload shapes. Full configs are only
ever lowered (ShapeDtypeStruct dry-run); reduced() variants run on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation for the config
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE half-dim sections (qwen2-vl)
    # mlp
    d_ff: int = 0
    # moe
    num_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # hybrid (recurrentgemma): repeating block pattern of layer kinds
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rglru", "rglru", "attn")
    window: int = 0                        # local-attention window
    lru_width: int = 0
    # modality frontend stub (vlm / audio): precomputed embeddings input
    multimodal: bool = False
    mm_embed_dim: int = 0
    # long-context policy for long_500k decode
    long_context: str = "skip"             # "native" | "sliding_window" | "skip"
    sliding_window: int = 8192
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def attn_layers(self) -> Tuple[str, ...]:
        """Per-layer kind sequence for the full depth."""
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.block_pattern:
            seq = []
            while len(seq) < self.num_layers:
                seq.extend(self.block_pattern)
            return tuple(seq[: self.num_layers])
        if self.num_experts > 0:
            return ("moe",) * self.num_layers
        return ("attn",) * self.num_layers

    @property
    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        p = 0
        embed = self.vocab_size * self.d_model
        p += embed
        if not self.tie_embeddings:
            p += embed
        for kind in self.attn_layers:
            if kind in ("attn", "moe"):
                q = self.d_model * self.num_heads * self.head_dim
                kv = 2 * self.d_model * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * self.d_model
                p += q + kv + o
            if kind == "attn":
                p += 3 * self.d_model * self.d_ff
            elif kind == "moe":
                p += 3 * self.d_model * self.d_ff * self.num_experts
                p += self.d_model * self.num_experts  # router
                if self.shared_expert:
                    p += 3 * self.d_model * self.d_ff
            elif kind == "ssm":
                d_inner = self.ssm_expand * self.d_model
                nheads = d_inner // self.ssm_head_dim
                in_proj = self.d_model * (2 * d_inner + 2 * self.ssm_state + nheads)
                p += in_proj + d_inner * self.d_model
            elif kind == "rglru":
                w = self.lru_width or self.d_model
                p += 2 * self.d_model * w + w * self.d_model + 3 * w
                p += 3 * self.d_model * self.d_ff  # griffin blocks carry an MLP too
        # hybrid local-attn layers also carry an MLP; handled above via "attn"
        return p

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k (+shared) experts)."""
        if self.num_experts == 0:
            return self.param_count
        dense_like = self.param_count
        dense_like -= 3 * self.d_model * self.d_ff * self.num_experts * self.num_layers
        active = self.top_k + (1 if self.shared_expert else 0)
        dense_like += 3 * self.d_model * self.d_ff * active * self.num_layers
        return dense_like

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        num_kv = min(self.num_kv_heads, num_heads) if self.num_kv_heads else 0
        head_dim = 32 if self.head_dim else 0
        updates = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=max(num_kv, 1) if num_heads else 0,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=8.0 if self.num_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 64,
            lru_width=min(self.lru_width, d_model) if self.lru_width else 0,
            window=min(self.window, 64) if self.window else 0,
            sliding_window=64,
            mm_embed_dim=min(self.mm_embed_dim, 64) if self.mm_embed_dim else 0,
            mrope_sections=(8, 4, 4) if self.mrope_sections else (),
            block_pattern=self.block_pattern,
            dtype="float32",
        )
        return dataclasses.replace(self, **updates)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
