"""Yi-9B [arXiv:2403.04652]. Llama-arch GQA kv=4."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    vocab_size=64000,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    rope_theta=10_000.0,
    long_context="sliding_window",
)
