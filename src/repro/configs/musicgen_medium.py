"""MusicGen-medium decoder [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens; the EnCodec conv codec +
conditioning (T5) frontend is stubbed: input_specs() provides conditioning
embeddings, the model consumes audio-token ids directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    vocab_size=2048,
    num_heads=24,
    num_kv_heads=24,          # MHA
    head_dim=64,
    d_ff=6144,
    multimodal=True,          # conditioning embeddings (stub frontend)
    mm_embed_dim=768,
    rope_theta=10_000.0,
    long_context="sliding_window",
)
