"""Granite-34B-Code [arXiv:2405.04324]. Deep llama-arch with MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    vocab_size=49152,
    num_heads=48,
    num_kv_heads=1,           # MQA
    head_dim=128,
    d_ff=24576,
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context="sliding_window",
)
