"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B]. 128 experts, top-8, qk_norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                 # per-expert FFN dim
    num_experts=128,
    top_k=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
)
