"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]. Qwen1.5 arch, full MHA kv=32."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    vocab_size=92416,
    num_heads=32,
    num_kv_heads=32,          # MHA
    head_dim=128,
    d_ff=13440,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
)
