"""Qwen3-4B [hf:Qwen/Qwen3-8B family]. qk_norm + GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    qk_norm=True,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
)
