"""Llama-4 Scout 17B-active / 16 experts [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE with top-1 routed expert + one shared expert; early-fusion multimodal
(vision frontend stubbed to precomputed embeddings per the assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    vocab_size=202048,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    num_experts=16,
    top_k=1,
    shared_expert=True,
    multimodal=True,
    mm_embed_dim=1408,
    rope_theta=500_000.0,
    long_context="sliding_window",
)
