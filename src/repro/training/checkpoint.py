"""Pytree <-> .npz checkpointing (no orbax dependency)."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def save(path: str, tree: Any, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(path if path.endswith(".npz") else path + ".npz",
             __treedef__=np.frombuffer(
                 json.dumps({"n": len(leaves), "step": step}).encode(),
                 dtype=np.uint8),
             **arrays)


def restore(path: str, like: Any) -> Tuple[Any, int]:
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    meta = json.loads(bytes(data["__treedef__"]).decode())
    leaves_like, treedef = jax.tree.flatten(like)
    assert meta["n"] == len(leaves_like), "checkpoint/model structure mismatch"
    leaves = [data[f"leaf_{i}"] for i in range(meta["n"])]
    leaves = [np.asarray(a).astype(l.dtype) if hasattr(l, "dtype") else a
              for a, l in zip(leaves, leaves_like)]
    return jax.tree.unflatten(treedef, leaves), meta["step"]
