"""AdamW + cosine schedule + global-norm clipping, pure JAX (no optax)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # ()
    m: object              # pytree like params (fp32)
    v: object              # pytree like params (fp32)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def cosine_lr(step, *, peak: float = 3e-4, warmup: int = 100,
              total: int = 10_000, floor_frac: float = 0.1):
    warm = peak * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip: float = 1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), gnorm
