"""Token-batch pipeline: synthetic corpus stream with doc packing."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class TokenStream:
    """Deterministic synthetic corpus: zipf-ish unigram documents packed
    into fixed-length training sequences (next-token labels)."""

    def __init__(self, vocab: int, *, seed: int = 0, doc_mean: int = 512):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.doc_mean = doc_mean
        self._buf: list = []

    def _doc(self) -> np.ndarray:
        n = max(int(self.rng.exponential(self.doc_mean)), 16)
        # zipf-like skew, clipped to vocab
        toks = self.rng.zipf(1.3, n) % self.vocab
        return toks.astype(np.int32)

    def batches(self, batch: int, seq: int,
                mm_dim: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
        need = batch * (seq + 1)
        while True:
            while len(self._buf) < need:
                self._buf.extend(self._doc().tolist())
            flat = np.array(self._buf[:need], np.int32).reshape(batch, seq + 1)
            self._buf = self._buf[need:]
            out = {"tokens": flat[:, :-1], "labels": flat[:, 1:]}
            if mm_dim:
                out["mm_embeds"] = self.rng.normal(
                    0, 1, (batch, 16, mm_dim)).astype(np.float32)
            yield out
