"""Causal-LM training step (the train_4k workload shape)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import adamw_update, cosine_lr


def loss_fn(model: Model, params, tokens, labels, mm_embeds=None):
    logits = model.forward_train(params, tokens, mm_embeds=mm_embeds)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = labels >= 0
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def make_train_step(model: Model, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` = {"tokens": (B,S), "labels": (B,S)} (+ "mm_embeds" for
    multimodal configs). Jit/pjit is applied by the caller (the launcher
    decides shardings)."""

    def train_step(params, opt_state, batch):
        mm = batch.get("mm_embeds")
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch["tokens"], batch["labels"], mm)
        )(params)
        lr = cosine_lr(opt_state.step, peak=peak_lr, warmup=warmup,
                       total=total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step
