"""Paper-figure benchmarks (Fig. 6-11), driven by the §5.4 simulator over
the shared scenario. Each returns a list of CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.scenario import build_engine
from repro.core import ALL_POLICIES, BS, ECHO
from repro.core.estimator import RatePredictor
from repro.data import BurstyTrace


def _run(policy, seed=0, **kw):
    eng, online, offline, p = build_engine(policy, seed=seed, **kw)
    t0 = time.perf_counter()
    stats = eng.run(max_iters=200_000, until_time=p["duration"])
    wall = time.perf_counter() - t0
    return eng, stats, wall, p


# workload variants mirroring the paper's Fig.6 bars
FIG6_VARIANTS = {
    # CPU-scale LooGLE QA-Short-like (fast; shares the Fig.7-10 scenario)
    "loogle_short": dict(),
    # ShareGPT-like offline: no prefix sharing (questions_per_doc=1)
    "sharegpt": dict(n_docs=240, questions=1, doc_len=96, question_len=32,
                     offline_new=24),
    # paper-scale LooGLE: 8k-token docs, A100-40G-sized cache (9.5k blocks),
    # A100-magnitude coefficients
    "loogle_paper": dict(
        n_docs=18, questions=22, doc_len=8192, question_len=128,
        offline_new=32, num_blocks=9500, block_size=16, chunk_size=512,
        duration=120.0, online_rate=1.0, burst_rate=6.0, online_prompt=308,
        online_new=64, max_running=64,
        tm_kw=dict(alpha=1e-8, beta=2e-5, gamma=3e-6, delta=3e-6)),
}


def fig6_throughput_speedup():
    """Offline task throughput speedup over BS (paper Fig. 6; up to 3.3x)."""
    rows = []
    for variant, kw in FIG6_VARIANTS.items():
        tput = {}
        for pol in ALL_POLICIES:
            eng, stats, wall, _ = _run(pol, **kw)
            tput[pol.name] = stats.offline_throughput()
            rows.append((f"fig6.{variant}.tput.{pol.name}",
                         wall * 1e6 / max(len(stats.iterations), 1),
                         f"{tput[pol.name]:.1f}tok/s"))
        base = max(tput["BS"], 1e-9)
        for pol in ALL_POLICIES:
            rows.append((f"fig6.{variant}.speedup.{pol.name}", 0.0,
                         f"{tput[pol.name] / base:.3f}x"))
    return rows


def fig7_slo():
    """TTFT / TPOT attainment per policy (paper Fig. 7)."""
    rows = []
    for pol in ALL_POLICIES:
        eng, stats, wall, _ = _run(pol)
        on = [r for r in stats.finished if r.is_online and r.ttft() is not None]
        ttfts = sorted(r.ttft() for r in on)
        p99 = ttfts[int(0.99 * (len(ttfts) - 1))] if ttfts else float("nan")
        rows.append((f"fig7.{pol.name}.ttft_attain", 0.0,
                     f"{stats.slo_attainment('ttft'):.3f}"))
        rows.append((f"fig7.{pol.name}.tpot_attain", 0.0,
                     f"{stats.slo_attainment('tpot'):.3f}"))
        rows.append((f"fig7.{pol.name}.ttft_p99", 0.0, f"{p99:.3f}s"))
    return rows


def fig8_interplay():
    """Active online vs offline requests move in opposition (paper Fig. 8)."""
    eng, stats, wall, _ = _run(ECHO)
    on = np.array([r.n_online for r in stats.iterations], float)
    off = np.array([r.n_offline for r in stats.iterations], float)
    if len(on) > 4 and on.std() > 0 and off.std() > 0:
        corr = float(np.corrcoef(on, off)[0, 1])
    else:
        corr = float("nan")
    return [("fig8.online_offline_corr", 0.0, f"{corr:.3f}"),
            ("fig8.mean_active_online", 0.0, f"{on.mean():.2f}"),
            ("fig8.mean_active_offline", 0.0, f"{off.mean():.2f}")]


def fig9_hit_rate():
    """Offline prefix-cache hit ratio under online bursts (paper Fig. 9:
    Echo keeps it high & stable; LRU flushes it)."""
    rows = []
    for pol in ALL_POLICIES:
        eng, stats, wall, _ = _run(pol)
        rows.append((f"fig9.{pol.name}.offline_hit", 0.0,
                     f"{eng.bm.metrics.offline_hit_rate:.3f}"))
        rows.append((f"fig9.{pol.name}.punished_tokens", 0.0,
                     str(eng.bm.metrics.punished_tokens)))
    return rows


def fig10_memory():
    """Memory occupancy breakdown (paper Fig. 10)."""
    eng, stats, wall, _ = _run(ECHO)
    usages = [r.usage for r in stats.iterations]
    keys = ("running_online", "running_offline", "free_online",
            "free_offline", "unused")
    total = eng.bm.num_blocks
    rows = []
    for k in keys:
        frac = np.mean([u[k] for u in usages]) / total
        rows.append((f"fig10.mean_frac.{k}", 0.0, f"{frac:.3f}"))
    occupied = np.mean([u["running_online"] + u["running_offline"]
                        for u in usages]) / total
    rows.append(("fig10.mean_occupied", 0.0, f"{occupied:.3f}"))
    return rows


def fig11_trace_prediction():
    """mu+sigma sliding-window arrival-rate prediction vs actual (Fig. 11)."""
    trace = BurstyTrace(base_rate=4.0, tidal_period=1200.0, burst_rate=6.0,
                        burst_len=10.0, burst_prob=0.03, seed=7)
    arrivals = trace.sample(0, 1200)
    rp = RatePredictor(window=300.0)
    errs, preds = [], []
    ai = 0
    for t in np.arange(60, 1200, 30.0):
        while ai < len(arrivals) and arrivals[ai] <= t:
            rp.observe(arrivals[ai])
            ai += 1
        pred = rp.predict_rate(t)
        actual = sum(1 for a in arrivals if t <= a < t + 30.0) / 30.0
        preds.append(pred)
        errs.append(pred - actual)
    cover = np.mean([e >= 0 for e in errs])     # prediction should over-cover
    mae = float(np.mean(np.abs(errs)))
    return [("fig11.pred_mae_req_s", 0.0, f"{mae:.3f}"),
            ("fig11.over_coverage", 0.0, f"{cover:.3f}"),
            ("fig11.mean_pred", 0.0, f"{np.mean(preds):.3f}")]
