"""§5.2 table: time-model accuracy against real (wall-clock) engine runs on
the tiny model — fit on micro-benchmarks, validate on held-out batches —
plus the closed-loop view: convergence of the online-calibrated model
against a perturbed ground-truth clock (virtual, model-free)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.estimator import TimeModel
from repro.models import Model
from repro.models.paged import PagedRunner

_CFG = ModelConfig(name="bench-tiny", family="dense", source="bench",
                   num_layers=2, d_model=64, vocab_size=128, num_heads=4,
                   num_kv_heads=2, head_dim=16, d_ff=128, dtype="float32",
                   rope_theta=10_000.0)


def rows():
    model = Model(_CFG)
    params = model.init(jax.random.PRNGKey(0))
    bs, chunk = 8, 32
    runner = PagedRunner(model, params, num_pages=128, page_size=bs,
                         max_pages_per_seq=16, chunk_size=chunk)

    def t_prefill(l, reps=5):
        toks = list(range(l))
        bt = list(range((l + bs - 1) // bs + 1))
        runner.prefill_chunk(toks, 0, bt)
        t0 = time.perf_counter()
        for _ in range(reps):
            runner.prefill_chunk(toks, 0, bt)
        return (time.perf_counter() - t0) / reps

    def t_decode(nbatch, ctx, reps=5):
        toks = [1] * nbatch
        bts = [list(range(i * 8, i * 8 + 16)) for i in range(nbatch)]
        pos = [ctx] * nbatch
        runner.decode(toks, bts, pos)
        t0 = time.perf_counter()
        for _ in range(reps):
            runner.decode(toks, bts, pos)
        return (time.perf_counter() - t0) / reps

    tm = TimeModel()
    fit_p = [(l, t_prefill(l)) for l in (8, 16, 24, 32)]
    tm.fit_prefill(fit_p)
    fit_d = [(ctx, float(ctx), t_decode(b, ctx))
             for b in (1, 2, 4) for ctx in (16, 64)]
    tm.fit_decode(fit_d)

    out = []
    errs = []
    for l in (12, 28):
        want = t_prefill(l)
        got = tm.prefill_time([(0, l)])
        errs.append(abs(got - want) / want)
        out.append((f"estimator.prefill_l{l}", want * 1e6,
                    f"pred={got * 1e6:.0f}us err={errs[-1]:.2f}"))
    for b, ctx in ((2, 32), (4, 96)):
        want = t_decode(b, ctx)
        got = tm.decode_time([ctx] * b)
        errs.append(abs(got - want) / want)
        out.append((f"estimator.decode_b{b}_c{ctx}", want * 1e6,
                    f"pred={got * 1e6:.0f}us err={errs[-1]:.2f}"))
    out.append(("estimator.mean_rel_err", 0.0, f"{np.mean(errs):.3f}"))
    out.extend(convergence_rows())
    return out


def convergence_rows(scale: float = 2.0, jitter: float = 0.02):
    """Closed-loop accuracy: start from the stock A100 estimate, clock the
    engine with a ``scale``-x perturbed ground truth, and report how fast
    the ``OnlineCalibrator`` drives the relative error down (trailing-100
    mean per milestone) against the same run with refitting disabled."""
    import dataclasses

    from benchmarks.scenario import build_engine, time_model
    from repro.core import ECHO, OnlineCalibrator

    rows_out = []
    for mode, calibrate in (("static", False), ("calibrated", True)):
        clock = time_model().perturbed(scale=scale, jitter=jitter, seed=7)
        policy = dataclasses.replace(ECHO, calibrate=calibrate, name="conv")
        eng, _, _, p = build_engine(policy, clock_model=clock)
        if not calibrate:
            eng.calibrator = OnlineCalibrator.passive(eng.tm)
        eng.run(max_iters=30_000, until_time=p["duration"] * 6)
        cal = eng.calibrator
        for it, err in cal.convergence_curve(100)[:5]:
            rows_out.append((f"estimator.{mode}.rel_err_iter{it}", 0.0,
                             f"{err:.3f}"))
        rows_out.append((f"estimator.{mode}.refits", 0.0, str(cal.refits)))
    return rows_out
