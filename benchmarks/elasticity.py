"""Elastic-fleet benchmark: failure recovery, migrate-on-drain vs
recompute, and predictive autoscaling vs static over-provisioning.

Three paired experiments on the shared §7.1 scenario, each deterministic on
the virtual clock (same seeds + ``clone_requests(preserve_rid=True)`` make
the paired runs bit-comparable):

  recovery   — kill one replica mid-backlog (ChaosConfig) and compare the
               dead replica's re-dispatched online requests against the
               same rids in an identical no-chaos run. Gate: >= 95% of them
               finish, at >= 95% of their no-chaos SLO attainment.
  migration  — drain the busiest replica mid-run twice: once shipping its
               parked prefixes over the fabric (``migrate=True``), once
               recomputing them at the new home. Gate: migration must not
               lose offline throughput at equal-or-better SLO attainment.
  autoscale  — FleetController (RatePredictor sizing, FleetPlanner.probe
               capacity oracle) growing/shrinking from 1 replica vs a
               static fleet pinned at ``max_replicas``. Gate: SLO within 2
               points absolute of the static fleet with strictly fewer
               replica-seconds.

Standalone JSON mode (CI artifact + the bench-floor regression gate —
compare against benchmarks/baselines/elasticity.json via check_floor.py):
    PYTHONPATH=src:. python benchmarks/elasticity.py --json out.json
Tiny smoke mode (CI):
    PYTHONPATH=src:. python benchmarks/elasticity.py --smoke
"""
from __future__ import annotations

from benchmarks.scenario import build_scenario
from repro.cluster import ChaosConfig, ClusterSimulator, FleetController, \
    FleetPlanner
from repro.core import ECHO
from repro.core.simulator import clone_requests

SEED = 0
N_REPLICAS = 3
NUM_BLOCKS = 96           # per replica: fleet working set >> one cache
HOST_BLOCKS = 192         # host tier holds the prefixes a drain ships

# saturated co-serve: the offline corpus takes most of the run to clear,
# so a kill strands in-flight work and a drain still has queued offline
# requests (with parked prefixes) to re-home
SCENARIO = dict(duration=24.0, online_rate=7.0, burst_rate=14.0,
                burst_prob=0.08, online_new=48, n_docs=8, questions=96,
                num_blocks=NUM_BLOCKS)
SMOKE = dict(duration=8.0, online_rate=4.0, n_docs=3, questions=12,
             max_iters=6_000)

KILL_FRAC = 0.30          # kill this far into the run (burst + backlog up)
DRAIN_FRAC = 0.25         # drain the busiest replica this far into the run

# prefill-heavy offline (long shared docs, short answers) under steady
# online: re-homing a group costs one 640-token re-prefill without
# migration vs a ~5 ms fabric shipment with it — the regime where
# migrate-on-drain is first-order, not scheduling noise
MIG_SCENARIO = dict(duration=24.0, online_rate=5.0, burst_prob=0.0,
                    online_new=32, n_docs=16, questions=24, doc_len=640,
                    offline_new=8, num_blocks=NUM_BLOCKS)
MIG_SMOKE = dict(duration=8.0, online_rate=3.0, n_docs=6, questions=8,
                 max_iters=6_000)
MIG_HOST_BLOCKS = 256     # room to park every homed group's prefix

AUTO_MAX = 3              # static fleet size the autoscaler competes with
AUTO_SCENARIO = dict(duration=40.0, online_rate=2.0, burst_rate=12.0,
                     burst_len=6.0, burst_prob=0.10, n_docs=3, questions=12,
                     num_blocks=NUM_BLOCKS)
AUTO_SMOKE = dict(duration=12.0, questions=8, max_iters=6_000)


def _scenario(smoke: bool, base: dict, smoke_ov: dict):
    ov = dict(base)
    if smoke:
        ov.update(smoke_ov)
    max_iters = ov.pop("max_iters", 60_000)
    num_blocks = ov.pop("num_blocks", NUM_BLOCKS)
    tm, online, offline, p = build_scenario(seed=SEED, **ov)
    return tm, online, offline, p, num_blocks, max_iters


def _sim(tm, num_blocks, n_replicas=N_REPLICAS, host_blocks=HOST_BLOCKS,
         **kw):
    return ClusterSimulator(n_replicas, ECHO, num_blocks=num_blocks,
                            host_kv_blocks=host_blocks, time_model=tm,
                            seed=SEED, **kw)


def _submit(sim, online, offline):
    sim.submit_all(clone_requests(online, preserve_rid=True)
                   + clone_requests(offline, preserve_rid=True))


def _meets_slo(r) -> bool:
    if not r.slo:
        return True
    ttft, tpot = r.ttft(), r.tpot()
    return (ttft is None or ttft <= r.slo.ttft) and \
        (tpot is None or tpot <= r.slo.tpot)


def _mode_report(sim, stats):
    return {
        "offline_throughput": stats.offline_throughput(),
        "slo_ttft": stats.slo_attainment("ttft"),
        "slo_tpot": stats.slo_attainment("tpot"),
        "online_finished": stats.finished_counts()[0],
        "offline_finished": stats.finished_counts()[1],
        "replica_seconds": stats.replica_seconds,
        "migrations": stats.router.migrations,
        "migrated_blocks": stats.router.migrated_blocks,
        "migrated_bytes": stats.router.migrated_bytes,
        "redispatched_online": stats.redispatched_online,
        "redispatched_offline": stats.redispatched_offline,
        "lost_tokens": stats.lost_tokens,
    }


# --------------------------------------------------------------- recovery
def recovery(smoke: bool = False) -> dict:
    tm, online, offline, p, nb, max_iters = _scenario(smoke, SCENARIO, SMOKE)
    horizon = p["duration"] * 6
    kill_t = p["duration"] * KILL_FRAC

    # deterministic victim choice: replay to the kill instant once and take
    # the replica with online work in flight and the deepest offline
    # backlog — the worst replica to lose
    probe = _sim(tm, nb)
    _submit(probe, online, offline)
    probe.run(max_iters=max_iters, until_time=kill_t)

    def _onl(r):
        return sum(1 for q in r.inflight_requests(include_running=True)
                   if q.is_online)

    victim = max(probe.replicas,
                 key=lambda r: (_onl(r) > 0, r.offline_backlog(),
                                _onl(r), -r.id))

    base = _sim(tm, nb)
    _submit(base, online, offline)
    base_stats = base.run(max_iters=max_iters, until_time=horizon)

    sim = _sim(tm, nb, chaos=ChaosConfig(kills=[(kill_t, victim.id)]))
    _submit(sim, online, offline)
    stats = sim.run(max_iters=max_iters, until_time=horizon)

    online_rids = {r.rid for r in online}
    redis = [rid for k in stats.kills for rid in k.rids
             if rid in online_rids]
    fin_chaos = {r.rid: r for r in stats.merged().finished}
    fin_base = {r.rid: r for r in base_stats.merged().finished}
    recovered = [rid for rid in redis if rid in fin_chaos]
    slo_chaos = sum(_meets_slo(fin_chaos[rid]) for rid in recovered)
    slo_base = sum(rid in fin_base and _meets_slo(fin_base[rid])
                   for rid in redis)
    n = max(len(redis), 1)
    lat = stats.recovery_latencies()

    out = {"no_chaos": _mode_report(base, base_stats),
           "chaos_kill": _mode_report(sim, stats)}
    head = {
        "kill_t": kill_t,
        "redispatched_online": len(redis),
        "recovered_frac": len(recovered) / n,
        "recovered_slo_frac": slo_chaos / n,
        "baseline_slo_frac": slo_base / n,
        "worst_recovery_s": max(lat, default=0.0),
        # acceptance gate (a): the kill's re-dispatch must recover >= 95%
        # of the dead replica's unfinished online requests, within SLO
        # relative to the same rids in the no-chaos run
        "recovery_ok": bool(
            len(redis) > 0
            and len(recovered) >= 0.95 * len(redis)
            and slo_chaos >= 0.95 * slo_base - 1e-9),
    }
    return out, head


# -------------------------------------------------------------- migration
def migration(smoke: bool = False) -> dict:
    tm, online, offline, p, nb, max_iters = _scenario(smoke, MIG_SCENARIO,
                                                      MIG_SMOKE)
    horizon = p["duration"] * 6
    drain_t = p["duration"] * DRAIN_FRAC

    out = {}
    for mode, migrate in (("drain_migrate", True),
                          ("drain_recompute", False)):
        sim = _sim(tm, nb, host_blocks=MIG_HOST_BLOCKS, migrate=migrate)
        _submit(sim, online, offline)
        sim.run(max_iters=max_iters, until_time=drain_t)
        victim = max(sim.router.routable(),
                     key=lambda r: (r.offline_backlog(), -r.id))
        drained = sim.drain_replica(victim.id)
        stats = sim.run(max_iters=max_iters, until_time=horizon)
        rep = _mode_report(sim, stats)
        rep["drained_replica"] = victim.id if drained else None
        out[mode] = rep

    mig, rec = out["drain_migrate"], out["drain_recompute"]
    head = {
        "migration_tput_ratio": mig["offline_throughput"]
        / max(rec["offline_throughput"], 1e-9),
        "migration_slo_delta_ttft": mig["slo_ttft"] - rec["slo_ttft"],
        "migration_slo_delta_tpot": mig["slo_tpot"] - rec["slo_tpot"],
        # acceptance gate (b): shipping parked prefixes over the fabric
        # must beat recomputing them at the new home on offline throughput,
        # at equal-or-better SLO attainment
        "migration_wins": bool(
            mig["offline_throughput"] >= rec["offline_throughput"]
            and mig["slo_ttft"] >= rec["slo_ttft"] - 1e-9
            and mig["slo_tpot"] >= rec["slo_tpot"] - 1e-9),
    }
    return out, head


# -------------------------------------------------------------- autoscale
def autoscale(smoke: bool = False) -> dict:
    tm, online, offline, p, nb, max_iters = _scenario(smoke, AUTO_SCENARIO,
                                                      AUTO_SMOKE)
    horizon = p["duration"] * 6

    static = _sim(tm, nb, n_replicas=AUTO_MAX)
    _submit(static, online, offline)
    static_stats = static.run(max_iters=max_iters, until_time=horizon)

    ctrl = FleetController(min_replicas=1, max_replicas=AUTO_MAX,
                           interval=1.0, cooldown=2.0, queue_high=2,
                           bin_s=2.0)
    # capacity figure from the planner's sweep oracle (§5.4 run once
    # offline), not a hand-tuned constant
    ctrl.calibrate(FleetPlanner(tm, seed=SEED), online,
                   num_blocks=nb, duration=p["duration"] * 2)
    auto = _sim(tm, nb, n_replicas=1, autoscaler=ctrl, join_delay=0.5)
    _submit(auto, online, offline)
    auto_stats = auto.run(max_iters=max_iters, until_time=horizon)

    out = {"static": _mode_report(static, static_stats),
           "autoscale": _mode_report(auto, auto_stats)}
    rs_auto = auto_stats.replica_seconds
    rs_static = static_stats.replica_seconds
    head = {
        "rate_per_replica": ctrl.rate_per_replica,
        "replicas_added": ctrl.n_added,
        "replicas_drained": ctrl.n_drained,
        "replica_seconds_ratio": rs_auto / max(rs_static, 1e-9),
        "autoscale_slo_delta_ttft": out["autoscale"]["slo_ttft"]
        - out["static"]["slo_ttft"],
        "autoscale_slo_delta_tpot": out["autoscale"]["slo_tpot"]
        - out["static"]["slo_tpot"],
        # acceptance gate (c): the autoscaled fleet must hold SLO within 2
        # points absolute of the statically over-provisioned fleet while
        # spending strictly fewer replica-seconds
        "autoscale_ok": bool(
            out["autoscale"]["slo_ttft"]
            >= out["static"]["slo_ttft"] - 0.02
            and out["autoscale"]["slo_tpot"]
            >= out["static"]["slo_tpot"] - 0.02
            and rs_auto < rs_static),
    }
    return out, head


MODES = ("no_chaos", "chaos_kill", "drain_migrate", "drain_recompute",
         "static", "autoscale")


def results(smoke: bool = False) -> dict:
    out = {}
    head = {}
    for fn in (recovery, migration, autoscale):
        modes, h = fn(smoke)
        out.update(modes)
        head.update(h)
    out["headline"] = head
    return out


def rows():
    res = results()
    out = []
    for mode in MODES:
        r = res[mode]
        out.append((f"elasticity.{mode}.offline_tput", 0.0,
                    f"{r['offline_throughput']:.1f}"))
        out.append((f"elasticity.{mode}.slo_ttft", 0.0,
                    f"{r['slo_ttft']:.3f}"))
        out.append((f"elasticity.{mode}.slo_tpot", 0.0,
                    f"{r['slo_tpot']:.3f}"))
    h = res["headline"]
    out.append(("elasticity.recovered_frac", 0.0,
                f"{h['recovered_frac']:.3f}"))
    out.append(("elasticity.worst_recovery_s", 0.0,
                f"{h['worst_recovery_s']:.2f}"))
    out.append(("elasticity.recovery_ok", 0.0, str(h["recovery_ok"])))
    out.append(("elasticity.migration_tput_ratio", 0.0,
                f"{h['migration_tput_ratio']:.3f}"))
    out.append(("elasticity.migration_wins", 0.0,
                str(h["migration_wins"])))
    out.append(("elasticity.replica_seconds_ratio", 0.0,
                f"{h['replica_seconds_ratio']:.3f}"))
    out.append(("elasticity.autoscale_ok", 0.0, str(h["autoscale_ok"])))
    return out


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale run (CI): exercises kill/drain/"
                         "autoscale paths, skips the headline win checks")
    args = ap.parse_args()
    res = results(smoke=args.smoke)
    for mode in MODES:
        r = res[mode]
        print(f"{mode:>16}: tput {r['offline_throughput']:8.1f} tok/s  "
              f"ttft {r['slo_ttft']:.3f}  tpot {r['slo_tpot']:.3f}  "
              f"cost {r['replica_seconds']:6.1f} rep-s  "
              f"migrated {r['migrated_blocks']} blk  "
              f"redispatched {r['redispatched_online']}"
              f"+{r['redispatched_offline']}")
    h = res["headline"]
    print(f"headline: recovery {h['recovered_frac']:.0%} of "
          f"{h['redispatched_online']} online "
          f"(worst {h['worst_recovery_s']:.2f}s)  "
          f"recovery_ok={h['recovery_ok']}")
    print(f"          migration x{h['migration_tput_ratio']:.2f} vs "
          f"recompute (dTTFT {h['migration_slo_delta_ttft']:+.3f})  "
          f"migration_wins={h['migration_wins']}")
    print(f"          autoscale {h['replica_seconds_ratio']:.0%} of static "
          f"cost (dTTFT {h['autoscale_slo_delta_ttft']:+.3f}, "
          f"+{h['replicas_added']}/-{h['replicas_drained']} replicas)  "
          f"autoscale_ok={h['autoscale_ok']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")
    if not args.smoke:
        if not h["recovery_ok"]:
            raise SystemExit("kill re-dispatch did not recover >=95% of the "
                             "dead replica's online requests within SLO")
        if not h["migration_wins"]:
            raise SystemExit("KV migration on drain did not beat recompute "
                             "at equal-or-better SLO attainment")
        if not h["autoscale_ok"]:
            raise SystemExit("autoscaled fleet missed the static fleet's "
                             "SLO by >2 points or spent more "
                             "replica-seconds")


if __name__ == "__main__":
    main()
