"""Kernel bench-floor gate for CI: compare a fresh
``kernels_micro.py --json`` run against the committed baseline.

Two gates per kernel row:

  * numerics — every ``pallas_matches`` boolean is a HARD gate: a False
    anywhere in the current run fails, baseline or not. A kernel that
    disagrees with ``kernels/ref.py`` is wrong, never merely slow.
  * timing — ``us_per_call`` must stay under ``baseline * (1 + tolerance)``
    (lower is better; improvements always pass). Unlike the virtual-clock
    floors in ``check_floor.py`` these are wall timings on shared CI
    runners, so the default tolerance is generous (1.0 → a 2x ceiling):
    it catches an accidental algorithmic regression — a gather-path
    fallback, a lost jit cache — without flaking on machine noise.

To accept an intentional change, regenerate the baseline in-repo:

    PYTHONPATH=src:. python benchmarks/kernels_micro.py \
        --json benchmarks/baselines/kernels_micro.json
"""
from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, tolerance: float) -> list:
    """Returns a list of human-readable violations (empty = pass)."""
    violations = []
    for name, cur in current.items():
        if not cur.get("pallas_matches", False):
            violations.append(
                f"{name}.pallas_matches: False — kernel disagrees with "
                "kernels/ref.py (hard gate)")
    for name, base in baseline.items():
        cur = current.get(name)
        if cur is None:
            violations.append(f"{name}: missing from current results")
            continue
        ceiling = base["us_per_call"] * (1.0 + tolerance)
        if cur["us_per_call"] > ceiling:
            violations.append(
                f"{name}.us_per_call: {cur['us_per_call']:.1f} > ceiling "
                f"{ceiling:.1f} (baseline {base['us_per_call']:.1f} "
                f"+{tolerance:.0%})")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="JSON from a fresh benchmarks/kernels_micro.py "
                         "--json run")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/kernels_micro.json",
                    help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="relative us_per_call headroom over baseline "
                         "(default 1.0 = 2x ceiling; wall time on shared "
                         "runners is noisy)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    violations = check(current, baseline, args.tolerance)
    if violations:
        print("kernel benchmark floor violated:", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        print("if intentional, refresh the baseline:\n"
              "  PYTHONPATH=src:. python benchmarks/kernels_micro.py "
              "--json benchmarks/baselines/kernels_micro.json",
              file=sys.stderr)
        raise SystemExit(1)
    print(f"kernel floor ok: {len(baseline)} kernels match ref and sit "
          f"under {1.0 + args.tolerance:.1f}x baseline time")


if __name__ == "__main__":
    main()
