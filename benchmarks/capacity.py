"""§5.4 capacity planning: min resources for SLOs + offline throughput."""
from __future__ import annotations

import time

from benchmarks.scenario import time_model
from repro.core import SLO
from repro.core.simulator import estimate_capacity
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests


def rows():
    tm = time_model()
    trace = BurstyTrace(base_rate=4.0, tidal_period=120.0, burst_rate=6.0,
                        burst_len=8.0, burst_prob=0.05, seed=11)
    arrivals = trace.sample(0, 30)
    online = make_online_requests(arrivals, prompt_mean=128, prompt_std=32,
                                  max_new_mean=24, slo=SLO(1.0, 0.1), seed=12)
    offline = make_offline_corpus(8, 16, doc_len=256, question_len=32,
                                  max_new=16, seed=13)
    t0 = time.perf_counter()
    rep = estimate_capacity(online, offline, tm,
                            candidate_blocks=(32, 64, 128, 256, 512),
                            slo_target=0.9, duration=30.0)
    wall = (time.perf_counter() - t0) * 1e6
    out = [("capacity.min_blocks_for_slo", wall,
            str(rep.min_blocks_for_slo))]
    for nb, att in rep.slo_by_blocks:
        out.append((f"capacity.slo_at_{nb}blocks", 0.0, f"{att:.3f}"))
    if rep.offline_throughput is not None:
        out.append(("capacity.offline_tput_at_min", 0.0,
                    f"{rep.offline_throughput:.1f}tok/s"))
    return out
