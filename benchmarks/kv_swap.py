"""Host-tier KV swap benchmark: recompute-only vs swap-enabled Echo, with
the swap/compute-overlap column (serial vs async-staged PCIe traffic).

The shared §7.1 burst scenario at elevated memory pressure (half the device
blocks of the default): online bursts flush the offline prefix working set,
and without a host tier every flushed block is re-prefilled from scratch —
exactly the recompute the KV manager exists to avoid (§4.2). With the swap
tier, evicted blocks with future reuse are parked in host memory and
restored over PCIe when the scheduler prices the transfer under the
recompute (Eq.6 vs. the TimeModel's swap terms).

Three paged modes:
  recompute    — no host tier (every punished eviction recomputes)
  swap_serial  — host tier, PCIe charged serially per iteration (PR 4)
  swap         — host tier, transfers overlapped with compute: the clock
                 charges max(compute, transfer) + launch, and the scheduler
                 only prices the *exposed* transfer tail against the SLO

State-family column (``--arch mamba2``, also part of the default run): the
same scenario priced over fixed-size recurrent-state snapshots instead of
per-token KV pages (restore_last_only — a restore moves ONE snapshot no
matter how deep the prefix). Two modes, state_recompute / state_swap,
headline ``state_swap_wins`` mirrors gate 1.

Reported per mode: offline throughput, SLO attainment, swap traffic,
punished (future-needed, recompute-bound) tokens, and the overlap
transfer/exposed split. Headlines: swap vs recompute throughput ratio and
overlap-on vs overlap-off ratio, each at equal-or-better SLO attainment.

Standalone JSON mode (CI artifact + the bench-floor regression gate —
compare against benchmarks/baselines/kv_swap.json via check_floor.py):
    PYTHONPATH=src:. python benchmarks/kv_swap.py --json out.json
Tiny smoke mode (CI):
    PYTHONPATH=src:. python benchmarks/kv_swap.py --smoke
"""
from __future__ import annotations

from benchmarks.scenario import build_engine
from repro.core import ECHO

SEED = 0
HOST_BLOCKS = 320          # ~1.3x the device budget, a fraction of host RAM
# Half the default device blocks: the offline working set (10 docs x 20
# blocks) no longer survives online bursts on device — the regime where
# swap-vs-recompute decides throughput.
OVERRIDES = dict(num_blocks=128, burst_rate=10.0, burst_prob=0.08)
SMOKE = dict(duration=8.0, n_docs=3, questions=12, num_blocks=64,
             max_iters=4_000)

MODES = (("recompute", 0, True),
         ("swap_serial", HOST_BLOCKS, False),
         ("swap", HOST_BLOCKS, True))
# same burst scenario, priced over recurrent-state snapshots (virtual clock:
# no runner is built, only the BlockIOSpec byte pricing differs)
STATE_ARCH = "mamba2-1.3b"
STATE_MODES = (("state_recompute", 0, True),
               ("state_swap", HOST_BLOCKS, True))


def _state_io():
    from repro.configs import get_config
    from repro.core.block_io import io_spec_for_model
    from repro.models import Model
    return io_spec_for_model(Model(get_config(STATE_ARCH).reduced()))


def _run(host_blocks: int, swap_overlap: bool, overrides=None,
         max_iters: int = 60_000):
    ov = dict(OVERRIDES)
    ov.update(overrides or {})
    eng, online, offline, p = build_engine(
        ECHO, seed=SEED, host_kv_blocks=host_blocks,
        tm_kw=dict(swap_overlap=swap_overlap), **ov)
    stats = eng.run(max_iters=max_iters, until_time=p["duration"] * 6)
    return eng, stats, online, offline


def obs_overhead(overrides=None, max_iters: int = 60_000,
                 trace_out=None, metrics_out=None, pairs: int = 2):
    """Wall-time ratio of the instrumented "swap" run over the bare one —
    the ISSUE-6 bounded-overhead gate (check_floor enforces <= 1 + tol).

    Runs ``pairs`` alternating bare/instrumented repeats and compares the
    best of each, which strips one-off machine noise while still charging
    every per-iteration cost the tracer and probes add. The last
    instrumented run's artifacts are optionally written (CI uploads them)."""
    import time as _t

    from repro.obs import MetricsRegistry, Tracer, instrument_engine

    ov = dict(OVERRIDES)
    ov.update(overrides or {})
    bare, instr = [], []
    tracer = registry = None
    for _ in range(pairs):
        eng, _, _, p = build_engine(ECHO, seed=SEED,
                                    host_kv_blocks=HOST_BLOCKS,
                                    tm_kw=dict(swap_overlap=True), **ov)
        t0 = _t.perf_counter()
        eng.run(max_iters=max_iters, until_time=p["duration"] * 6)
        bare.append(_t.perf_counter() - t0)

        eng, _, _, p = build_engine(ECHO, seed=SEED,
                                    host_kv_blocks=HOST_BLOCKS,
                                    tm_kw=dict(swap_overlap=True), **ov)
        registry, tracer = MetricsRegistry(), Tracer()
        instrument_engine(eng, registry, tracer)
        t0 = _t.perf_counter()
        eng.run(max_iters=max_iters, until_time=p["duration"] * 6)
        instr.append(_t.perf_counter() - t0)
    if trace_out and tracer is not None:
        tracer.write(trace_out)
    if metrics_out and registry is not None:
        registry.write(metrics_out)
    return {"obs_overhead": min(instr) / max(min(bare), 1e-9),
            "bare_wall": min(bare), "instrumented_wall": min(instr)}


def _mode_report(eng, stats, host, overlap):
    m = eng.bm.metrics
    return {
        "host_blocks": host,
        "swap_overlap": overlap,
        "io_family": eng.bm.io.family,
        "offline_throughput": stats.offline_throughput(),
        "slo_ttft": stats.slo_attainment("ttft"),
        "slo_tpot": stats.slo_attainment("tpot"),
        "online_finished": sum(1 for r in stats.finished if r.is_online),
        "offline_finished": sum(1 for r in stats.finished
                                if not r.is_online),
        "evictions": m.evictions,
        "punished_tokens": m.punished_tokens,
        "swapped_out_tokens": m.swapped_out_tokens,
        "swapped_in_tokens": m.swapped_in_tokens,
        "swapped_out_bytes": m.swapped_out_bytes,
        "swapped_in_bytes": m.swapped_in_bytes,
        "host_bounced_blocks": m.host_bounced_blocks,
        "swap_transfer_time": stats.swap_transfer_time,
        "swap_exposed_time": stats.swap_exposed_time,
        "swap_hidden_frac": stats.swap_hidden_frac(),
    }


def results(smoke: bool = False, trace_out=None, metrics_out=None,
            arch: str = "all"):
    overrides = dict(SMOKE) if smoke else {}
    max_iters = overrides.pop("max_iters", 60_000)
    out = {}
    if arch in ("all", "paged"):
        for mode, host, overlap in MODES:
            eng, stats, online, offline = _run(host, overlap, overrides,
                                               max_iters)
            out[mode] = _mode_report(eng, stats, host, overlap)
    if arch in ("all", "mamba2"):
        state_ov = dict(overrides)
        state_ov["io_spec"] = _state_io()
        for mode, host, overlap in STATE_MODES:
            eng, stats, online, offline = _run(host, overlap, state_ov,
                                               max_iters)
            out[mode] = _mode_report(eng, stats, host, overlap)
    if arch == "mamba2":
        srec, ssw = out["state_recompute"], out["state_swap"]
        out["headline"] = _state_headline(srec, ssw)
        return out
    rec, ser, sw = out["recompute"], out["swap_serial"], out["swap"]
    out["headline"] = {
        "tput_ratio": sw["offline_throughput"]
        / max(rec["offline_throughput"], 1e-9),
        "slo_delta_ttft": sw["slo_ttft"] - rec["slo_ttft"],
        "slo_delta_tpot": sw["slo_tpot"] - rec["slo_tpot"],
        "punished_tokens_saved": rec["punished_tokens"]
        - sw["punished_tokens"],
        # acceptance gate 1 (PR 4): swap-enabled must match recompute-only's
        # SLO attainment while completing at least as much offline work
        "swap_wins": bool(
            sw["offline_throughput"] >= rec["offline_throughput"]
            and sw["slo_ttft"] >= rec["slo_ttft"] - 1e-9
            and sw["slo_tpot"] >= rec["slo_tpot"] - 1e-9),
        # acceptance gate 2 (this PR): overlapping the transfers must not
        # lose to charging them serially — same tokens, cheaper clock
        "overlap_tput_ratio": sw["offline_throughput"]
        / max(ser["offline_throughput"], 1e-9),
        "overlap_slo_delta_ttft": sw["slo_ttft"] - ser["slo_ttft"],
        "overlap_slo_delta_tpot": sw["slo_tpot"] - ser["slo_tpot"],
        "overlap_hidden_frac": sw["swap_hidden_frac"],
        "overlap_wins": bool(
            sw["offline_throughput"] >= ser["offline_throughput"]
            and sw["slo_ttft"] >= ser["slo_ttft"] - 1e-9
            and sw["slo_tpot"] >= ser["slo_tpot"] - 1e-9),
    }
    if arch == "all":
        out["headline"].update(_state_headline(out["state_recompute"],
                                               out["state_swap"]))
    # acceptance gate 3 (ISSUE 6): observability must stay cheap — re-run
    # the swap mode with tracer + probes attached and compare wall clocks
    out["headline"].update(obs_overhead(
        overrides, max_iters, trace_out=trace_out, metrics_out=metrics_out,
        pairs=1 if smoke else 2))
    return out


def _state_headline(srec, ssw):
    """Acceptance gate (this PR): snapshot restore must not lose to
    recompute-only at equal-or-better SLO attainment."""
    return {
        "state_tput_ratio": ssw["offline_throughput"]
        / max(srec["offline_throughput"], 1e-9),
        "state_slo_delta_ttft": ssw["slo_ttft"] - srec["slo_ttft"],
        "state_slo_delta_tpot": ssw["slo_tpot"] - srec["slo_tpot"],
        "state_swap_wins": bool(
            ssw["offline_throughput"] >= srec["offline_throughput"]
            and ssw["slo_ttft"] >= srec["slo_ttft"] - 1e-9
            and ssw["slo_tpot"] >= srec["slo_tpot"] - 1e-9),
    }


def rows():
    res = results()
    out = []
    for mode, _, _ in (*MODES, *STATE_MODES):
        r = res[mode]
        out.append((f"kv_swap.{mode}.offline_tput", 0.0,
                    f"{r['offline_throughput']:.1f}"))
        out.append((f"kv_swap.{mode}.slo_ttft", 0.0, f"{r['slo_ttft']:.3f}"))
        out.append((f"kv_swap.{mode}.slo_tpot", 0.0, f"{r['slo_tpot']:.3f}"))
        out.append((f"kv_swap.{mode}.punished_tokens", 0.0,
                    f"{r['punished_tokens']}"))
    h = res["headline"]
    out.append(("kv_swap.tput_ratio", 0.0, f"{h['tput_ratio']:.3f}"))
    out.append(("kv_swap.swap_wins", 0.0, str(h["swap_wins"])))
    out.append(("kv_swap.overlap_tput_ratio", 0.0,
                f"{h['overlap_tput_ratio']:.3f}"))
    out.append(("kv_swap.overlap_hidden_frac", 0.0,
                f"{h['overlap_hidden_frac']:.3f}"))
    out.append(("kv_swap.overlap_wins", 0.0, str(h["overlap_wins"])))
    out.append(("kv_swap.state_tput_ratio", 0.0,
                f"{h['state_tput_ratio']:.3f}"))
    out.append(("kv_swap.state_swap_wins", 0.0, str(h["state_swap_wins"])))
    out.append(("kv_swap.obs_overhead", 0.0, f"{h['obs_overhead']:.3f}"))
    return out


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale run (CI): exercises the swap path, "
                         "skips the headline win checks")
    ap.add_argument("--trace-out", default=None,
                    help="write the instrumented run's Chrome trace here "
                         "(CI artifact)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the instrumented run's metrics snapshot "
                         "here (CI artifact)")
    ap.add_argument("--arch", default="all",
                    choices=("all", "paged", "mamba2"),
                    help="block I/O family: paged KV, mamba2 state "
                         "snapshots, or both (default)")
    args = ap.parse_args()
    res = results(smoke=args.smoke, trace_out=args.trace_out,
                  metrics_out=args.metrics_out, arch=args.arch)
    for mode, _, _ in (*MODES, *STATE_MODES):
        r = res.get(mode)
        if r is None:
            continue
        print(f"{mode:>15}: tput {r['offline_throughput']:8.1f} tok/s  "
              f"ttft {r['slo_ttft']:.3f}  tpot {r['slo_tpot']:.3f}  "
              f"punished {r['punished_tokens']:6d}  "
              f"swap in/out {r['swapped_in_tokens']}/"
              f"{r['swapped_out_tokens']}  "
              f"hidden {r['swap_hidden_frac']:.0%}")
    h = res["headline"]
    if "tput_ratio" in h:
        print(f"headline: swap x{h['tput_ratio']:.2f} vs recompute "
              f"(dTTFT {h['slo_delta_ttft']:+.3f} dTPOT "
              f"{h['slo_delta_tpot']:+.3f})  swap_wins={h['swap_wins']}")
        print(f"          overlap x{h['overlap_tput_ratio']:.2f} vs serial "
              f"(hidden {h['overlap_hidden_frac']:.0%})  "
              f"overlap_wins={h['overlap_wins']}")
    if "state_tput_ratio" in h:
        print(f"          state swap x{h['state_tput_ratio']:.2f} vs "
              f"recompute (dTTFT {h['state_slo_delta_ttft']:+.3f})  "
              f"state_swap_wins={h['state_swap_wins']}")
    if "obs_overhead" in h:
        print(f"          obs overhead x{h['obs_overhead']:.3f} "
              f"({h['bare_wall']:.2f}s bare, "
              f"{h['instrumented_wall']:.2f}s instrumented)")
    if args.trace_out:
        print(f"wrote {args.trace_out}")
    if args.metrics_out:
        print(f"wrote {args.metrics_out}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")
    if not args.smoke:
        if not h.get("swap_wins", True):
            raise SystemExit("swap-enabled Echo did not beat recompute-only "
                             "at equal-or-better SLO attainment")
        if not h.get("overlap_wins", True):
            raise SystemExit("overlapped swap did not beat serial swap at "
                             "equal-or-better SLO attainment")
        if not h.get("state_swap_wins", True):
            raise SystemExit("state-snapshot swap did not beat "
                             "recompute-only at equal-or-better SLO "
                             "attainment")


if __name__ == "__main__":
    main()
