"""Calibration benchmark: static vs. online-calibrated estimates under a
miscalibrated ground-truth clock (§5 closed-loop).

The engine's scheduler starts from the stock A100 estimate while the
ground-truth clock runs 2x slower (plus seeded jitter) — the regime where a
static estimate admits offline work the hardware cannot absorb and SLO
shedding fires too late. Reported: estimator convergence (mean relative
iteration-time error per trailing window), SLO attainment, and offline
throughput for the static and calibrated runs.

Standalone JSON mode (CI artifact):
    PYTHONPATH=src:. python benchmarks/calibration.py --json out.json
"""
from __future__ import annotations

import dataclasses

from benchmarks.scenario import build_engine, time_model
from repro.core import ECHO, SLO, OnlineCalibrator

MISCALIBRATION = 2.0      # ground truth runs 2x slower than the estimate
JITTER = 0.02             # per-iteration log-normal noise sigma
WARMUP_FRAC = 0.25        # iterations ignored when reporting converged error
SEED = 0
# Tighter than the shared scenario: with 2x-slow hardware the static
# estimate both under-sheds (TPOT misses) and mis-prices offline admission
# — the regime where the closed loop visibly pays off.
OVERRIDES = dict(online_rate=3.0, slo=SLO(0.6, 0.05))


def _run(calibrate: bool):
    policy = dataclasses.replace(ECHO, calibrate=calibrate,
                                 name=ECHO.name + ("+C" if calibrate else ""))
    clock = time_model().perturbed(scale=MISCALIBRATION, jitter=JITTER,
                                   seed=SEED + 40)
    eng, online, offline, p = build_engine(policy, seed=SEED,
                                           clock_model=clock, **OVERRIDES)
    if not calibrate:
        # records estimate-vs-clock error, never refits
        eng.calibrator = OnlineCalibrator.passive(eng.tm)
    stats = eng.run(max_iters=60_000, until_time=p["duration"] * 6)
    return eng, stats, online, offline


def results():
    out = {}
    for mode, calibrate in (("static", False), ("calibrated", True)):
        eng, stats, online, offline = _run(calibrate)
        cal = eng.calibrator
        n = len(cal.history)
        warm = max(int(n * WARMUP_FRAC), 1)
        out[mode] = {
            "iterations": n,
            "refits": cal.refits,
            "rel_err_overall": cal.mean_rel_err(),
            "rel_err_after_warmup": cal.mean_rel_err(n - warm),
            "convergence": cal.convergence_curve(100),
            "slo_ttft": stats.slo_attainment("ttft"),
            "slo_tpot": stats.slo_attainment("tpot"),
            "offline_throughput": stats.offline_throughput(),
            "online_finished": sum(1 for r in stats.finished if r.is_online),
            "offline_finished": sum(1 for r in stats.finished
                                    if not r.is_online),
        }
    st, ca = out["static"], out["calibrated"]
    out["headline"] = {
        "miscalibration": MISCALIBRATION,
        "err_static": st["rel_err_after_warmup"],
        "err_calibrated": ca["rel_err_after_warmup"],
        "slo_delta_ttft": ca["slo_ttft"] - st["slo_ttft"],
        "slo_delta_tpot": ca["slo_tpot"] - st["slo_tpot"],
        "tput_ratio": ca["offline_throughput"]
        / max(st["offline_throughput"], 1e-9),
    }
    return out


def rows():
    res = results()
    out = []
    for mode in ("static", "calibrated"):
        r = res[mode]
        out.append((f"calibration.{mode}.rel_err_after_warmup", 0.0,
                    f"{r['rel_err_after_warmup']:.3f}"))
        out.append((f"calibration.{mode}.refits", 0.0, str(r["refits"])))
        out.append((f"calibration.{mode}.slo_ttft", 0.0,
                    f"{r['slo_ttft']:.3f}"))
        out.append((f"calibration.{mode}.slo_tpot", 0.0,
                    f"{r['slo_tpot']:.3f}"))
        out.append((f"calibration.{mode}.offline_tput", 0.0,
                    f"{r['offline_throughput']:.1f}tok/s"))
    for i, err in res["calibrated"]["convergence"][:8]:
        out.append((f"calibration.convergence.iter{i}", 0.0, f"{err:.3f}"))
    h = res["headline"]
    out.append(("calibration.headline.err_reduction", 0.0,
                f"{h['err_static']:.3f}->{h['err_calibrated']:.3f}"))
    out.append(("calibration.headline.slo_delta", 0.0,
                f"ttft{h['slo_delta_ttft']:+.3f}/tpot{h['slo_delta_tpot']:+.3f}"))
    out.append(("calibration.headline.tput_ratio", 0.0,
                f"{h['tput_ratio']:.3f}x"))
    return out


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write full results as JSON to this path")
    args = ap.parse_args()
    res = results()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")
    h = res["headline"]
    print(f"static    : err={h['err_static']:.3f}  "
          f"slo_ttft={res['static']['slo_ttft']:.3f}  "
          f"tput={res['static']['offline_throughput']:.1f} tok/s")
    print(f"calibrated: err={h['err_calibrated']:.3f}  "
          f"slo_ttft={res['calibrated']['slo_ttft']:.3f}  "
          f"tput={res['calibrated']['offline_throughput']:.1f} tok/s  "
          f"(refits={res['calibrated']['refits']})")


if __name__ == "__main__":
    main()
