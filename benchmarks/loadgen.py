"""Real-time load generator: concurrent streaming clients against the
``AsyncEchoEngine`` front door.

Two traffic shapes:

  * **closed loop** — N clients, each submit -> stream -> repeat; measures
    the server at its concurrency limit (the ISSUE's 1k-client target);
  * **open loop** — Poisson arrivals on the wall clock; each arrival is an
    independent client task, so slow service builds real queueing instead
    of throttling the generator.

Both report wall-clock TTFT/TPOT percentiles (what a client measures, not
the backend's virtual clock), request/token throughput, shed/abort counts,
and two acceptance checks: ``kv_leaks`` after graceful drain (all zero)
and a replay-equivalence ratio — the same workload, arrival stamps taken
from the live run, replayed through ``EchoService.drive`` on an
identically configured engine; engine-domain offline throughput must
match within 10% (the async loop only adds wall-clock plumbing, never
scheduling behavior).

CLI: ``python -m benchmarks.loadgen --clients 1000`` (full run),
``--smoke`` (50 clients, CI), ``--json out.json`` (latency artifact).
``rows()`` feeds the benchmark harness CSV at smoke scale.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ECHO, SLO, EchoEngine, Request, TaskType, TimeModel
from repro.core.simulator import clone_requests
from repro.serving import AdmissionConfig, EchoService, HandleStatus
from repro.rt import AsyncEchoEngine

NUM_BLOCKS = 512
BLOCK_SIZE = 16
CHUNK = 64
MAX_BATCH_TOKENS = 4096


def _engine() -> EchoEngine:
    return EchoEngine(None, None, ECHO, num_blocks=NUM_BLOCKS,
                      block_size=BLOCK_SIZE, chunk_size=CHUNK,
                      time_model=TimeModel.a100(),
                      max_batch_tokens=MAX_BATCH_TOKENS)


def _prompt(rng: np.random.Generator, mean: int = 32) -> List[int]:
    n = max(int(rng.normal(mean, mean / 4)), 4)
    return [int(t) for t in rng.integers(1, 1000, n)]


async def _client(rt: AsyncEchoEngine, rng: np.random.Generator, *,
                  iterations: int, max_new: int, slo: Optional[SLO],
                  results: List[Dict]) -> None:
    """One closed-loop client: submit, stream to the end, repeat."""
    for _ in range(iterations):
        h = await rt.submit(_prompt(rng), max_new_tokens=max_new, slo=slo)
        async for _ev in h.tokens():
            pass
        results.append({"status": h.status.value,
                        "ttft": h.wall_ttft(), "tpot": h.wall_tpot(),
                        "latency": h.wall_latency(),
                        "tokens": h.n_tokens})


async def _open_loop(rt: AsyncEchoEngine, rng: np.random.Generator, *,
                     rate: float, duration: float, max_new: int,
                     slo: Optional[SLO], results: List[Dict]) -> None:
    """Poisson arrivals on the wall clock; one task per arrival."""
    tasks = []
    t_end = time.monotonic() + duration
    while time.monotonic() < t_end:
        await asyncio.sleep(float(rng.exponential(1.0 / rate)))
        tasks.append(asyncio.ensure_future(
            _client(rt, rng, iterations=1, max_new=max_new, slo=slo,
                    results=results)))
    await asyncio.gather(*tasks)


def _percentiles(vals: List[float]) -> Dict[str, float]:
    if not vals:
        return {}
    arr = np.asarray(vals, np.float64)
    return {f"p{int(q * 100)}": float(np.percentile(arr, q * 100))
            for q in (0.5, 0.9, 0.99)}


def _replay_ratio(requests: List[Request], live_tput: float) -> float:
    """Replay the live run's workload (arrival stamps included) through the
    synchronous ``drive`` path on a fresh identical engine and compare
    engine-domain offline throughput. ~1.0 means the async front door left
    the scheduler's behavior untouched."""
    clones = clone_requests(requests)
    clones.sort(key=lambda r: r.arrival_time)
    svc = EchoService(_engine())
    stats = svc.drive(clones, max_iters=200_000)
    ref = stats.offline_throughput()
    if ref <= 0.0:
        return 1.0 if live_tput <= 0.0 else 0.0
    return live_tput / ref


async def _run(args) -> Dict:
    rng = np.random.default_rng(args.seed)
    admission = (AdmissionConfig(max_online_queue=args.max_online_queue)
                 if args.max_online_queue else None)
    rt = AsyncEchoEngine(_engine(), admission=admission,
                         max_submit_queue=max(4 * args.clients, 1024),
                         steps_per_hop=args.steps_per_hop)
    reg = rt.instrument()
    slo = SLO(args.slo_ttft, args.slo_tpot) if args.slo_ttft else None
    results: List[Dict] = []
    submitted: List[Request] = []
    rt.service.events.on_finish(lambda h: submitted.append(h.request))
    rt.service.events.on_abort(lambda h: submitted.append(h.request))

    # background offline corpus: makes the replay-equivalence check
    # exercise the co-scheduling path, not just online decode
    offline_handles = []
    t0 = time.monotonic()
    await rt.start()
    for _ in range(args.offline):
        offline_handles.append(await rt.submit(
            _prompt(rng, 96), max_new_tokens=args.max_new * 2,
            task_type=TaskType.OFFLINE))
    if args.open_rate > 0:
        await _open_loop(rt, rng, rate=args.open_rate,
                         duration=args.duration, max_new=args.max_new,
                         slo=slo, results=results)
    else:
        await asyncio.gather(*[
            _client(rt, np.random.default_rng(args.seed + 1 + i),
                    iterations=args.iterations, max_new=args.max_new,
                    slo=slo, results=results)
            for i in range(args.clients)])
    await rt.drain()
    wall = time.monotonic() - t0

    leaks = rt.kv_leaks()
    live_tput = rt.service.live.offline_throughput() if args.offline \
        else rt.service.engine.stats.offline_throughput()
    ratio = _replay_ratio(submitted, live_tput) if args.replay_check else None
    offline_finished = 0
    for h in offline_handles:
        res = await h.result()
        offline_finished += res.status is HandleStatus.FINISHED
    ttfts = [r["ttft"] for r in results if r["ttft"] is not None]
    tpots = [r["tpot"] for r in results if r["tpot"] is not None]
    finished = sum(r["status"] == "finished" for r in results)
    report = {
        "mode": "open" if args.open_rate > 0 else "closed",
        "clients": args.clients if args.open_rate <= 0 else None,
        "open_rate": args.open_rate or None,
        "requests": len(results),
        "finished": finished,
        "shed": sum(r["status"] == "shed" for r in results),
        "aborted": sum(r["status"] == "aborted" for r in results),
        "offline_finished": offline_finished,
        "wall_seconds": wall,
        "requests_per_s": len(results) / wall if wall > 0 else 0.0,
        "tokens_per_s": sum(r["tokens"] for r in results) / wall
        if wall > 0 else 0.0,
        "ttft_wall": _percentiles(ttfts),
        "tpot_wall": _percentiles(tpots),
        "slo_attainment_ttft": rt.service.live.slo_attainment("ttft"),
        "offline_tput_engine": live_tput,
        "replay_tput_ratio": ratio,
        "kv_leaks": leaks,
        "leak_free": not any(leaks.values()),
        "peak_live": rt.stats.peak_live,
        "steps": rt.stats.steps,
        "slow_consumer_aborts": rt.stats.slow_consumer_aborts,
        "rt_ttft_p99_hist": reg.get("rt_ttft_wall_seconds").percentile(0.99),
    }
    return report


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--clients", type=int, default=1000,
                   help="closed-loop concurrent clients")
    p.add_argument("--iterations", type=int, default=2,
                   help="requests per closed-loop client")
    p.add_argument("--open-rate", type=float, default=0.0,
                   help="open-loop Poisson arrivals/s (overrides closed loop)")
    p.add_argument("--duration", type=float, default=10.0,
                   help="open-loop generation window, wall seconds")
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--offline", type=int, default=16,
                   help="background offline requests submitted at start")
    p.add_argument("--slo-ttft", type=float, default=2.0)
    p.add_argument("--slo-tpot", type=float, default=0.5)
    p.add_argument("--max-online-queue", type=int, default=0,
                   help="admission queue cap (0 = admission off)")
    p.add_argument("--steps-per-hop", type=int, default=8,
                   help="backend iterations per worker-thread round trip")
    p.add_argument("--no-replay-check", dest="replay_check",
                   action="store_false",
                   help="skip the drive() replay-equivalence comparison")
    p.add_argument("--smoke", action="store_true",
                   help="CI scale: 50 clients x 1 request")
    p.add_argument("--json", type=str, default=None,
                   help="write the full report to this path")
    p.add_argument("--seed", type=int, default=0)
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.smoke:
        args.clients = min(args.clients, 50)
        args.iterations = 1
        args.offline = min(args.offline, 8)
    report = asyncio.run(_run(args))
    for key in ("mode", "requests", "finished", "shed", "aborted",
                "wall_seconds", "requests_per_s", "tokens_per_s",
                "ttft_wall", "tpot_wall", "replay_tput_ratio",
                "leak_free", "peak_live"):
        print(f"{key}: {report[key]}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    ok = report["leak_free"] and (
        report["replay_tput_ratio"] is None
        or abs(report["replay_tput_ratio"] - 1.0) <= 0.10)
    return 0 if ok else 1


# ----------------------------------------------------------- harness rows
def rows():
    """Benchmark-harness entry: a smoke-scale closed-loop run."""
    args = _parser().parse_args([])
    args.clients, args.iterations, args.offline = 50, 1, 8
    t0 = time.perf_counter()
    report = asyncio.run(_run(args))
    wall_us = (time.perf_counter() - t0) * 1e6
    out = [("loadgen.closed50.requests_per_s", wall_us,
            f"{report['requests_per_s']:.0f}"),
           ("loadgen.closed50.ttft_p99_ms", wall_us,
            f"{report['ttft_wall'].get('p99', 0.0) * 1e3:.2f}"),
           ("loadgen.closed50.replay_ratio", wall_us,
            f"{report['replay_tput_ratio']:.3f}"),
           ("loadgen.closed50.leak_free", wall_us,
            str(report["leak_free"]).lower())]
    return out


if __name__ == "__main__":
    raise SystemExit(main())
