"""Cluster co-serving benchmark: fleet offline throughput + online SLO
attainment vs. replica count and vs. router policy.

Scenario: a multi-tenant mix (distinct SLO classes, private shared-prefix
corpora per tenant) whose *fleet-wide* offline prefix working set exceeds a
single replica's KV cache, while each tenant's subset fits. A
prefix-affinity router keeps each document group on one home replica (every
prefix computed once fleet-wide); round-robin/random scatter recomputes
each document on every replica and thrashes each replica's cache.
"""
from __future__ import annotations

import time

from benchmarks.scenario import time_model
from repro.cluster import ClusterSimulator, FleetPlanner
from repro.core import ECHO
from repro.core.simulator import clone_requests
from repro.data import default_tenants, make_multi_tenant_workload
from repro.serving import EchoService

DURATION = 30.0
NUM_BLOCKS = 128          # per replica; fleet working set >> one cache
REPLICA_SWEEP = (1, 2, 4)
POLICY_SWEEP = ("affinity", "round_robin", "random")
POLICY_REPLICAS = 3


def _workload():
    return make_multi_tenant_workload(default_tenants(3), DURATION, seed=5)


def _peak_workload():
    """§5.4 step 1 uses a short *peak* window: same tenants at flash-crowd
    rates, so the planner has to scale the fleet out."""
    import dataclasses
    peak = tuple(dataclasses.replace(t, online_rate=t.online_rate * 12)
                 for t in default_tenants(3))
    return make_multi_tenant_workload(peak, DURATION / 2, seed=6)


def _run(n_replicas, router_policy, online, offline, tm):
    sim = ClusterSimulator(n_replicas, ECHO, router_policy=router_policy,
                           num_blocks=NUM_BLOCKS, time_model=tm, seed=0)
    service = EchoService(sim)
    return service.drive(clone_requests(online) + clone_requests(offline),
                         until_time=DURATION * 4)


def rows():
    tm = time_model()
    online, offline = _workload()
    out = []

    # fleet scaling: throughput + SLO vs. replica count (affinity router)
    for n in REPLICA_SWEEP:
        t0 = time.perf_counter()
        stats = _run(n, "affinity", online, offline, tm)
        wall = (time.perf_counter() - t0) * 1e6
        att = min(stats.slo_attainment("ttft"), stats.slo_attainment("tpot"))
        out.append((f"cluster.scale.{n}rep.offline_tput", wall,
                    f"{stats.offline_throughput():.1f}tok/s"))
        out.append((f"cluster.scale.{n}rep.slo", 0.0, f"{att:.3f}"))

    # router ablation at fixed fleet size
    by_policy = {}
    for pol in POLICY_SWEEP:
        stats = _run(POLICY_REPLICAS, pol, online, offline, tm)
        att = min(stats.slo_attainment("ttft"), stats.slo_attainment("tpot"))
        tput = stats.offline_throughput()
        by_policy[pol] = (tput, att)
        out.append((f"cluster.router.{pol}.offline_tput", 0.0,
                    f"{tput:.1f}tok/s"))
        out.append((f"cluster.router.{pol}.slo", 0.0, f"{att:.3f}"))
        out.append((f"cluster.router.{pol}.affinity_hits", 0.0,
                    str(stats.router.affinity_hits)))
        out.append((f"cluster.router.{pol}.stolen", 0.0,
                    str(stats.router.stolen_requests)))
    # headline: affinity over round-robin (acceptance: speedup > 1 at
    # equal-or-better SLO)
    aff, rr = by_policy["affinity"], by_policy["round_robin"]
    out.append(("cluster.affinity_vs_rr.speedup", 0.0,
                f"{aff[0] / max(rr[0], 1e-9):.3f}x"))
    out.append(("cluster.affinity_vs_rr.slo_delta", 0.0,
                f"{aff[1] - rr[1]:+.3f}"))

    # fleet planning: min replicas x blocks for the SLO target on a peak
    # online window, co-served with the offline corpus
    planner = FleetPlanner(tm)
    peak_online, peak_offline = _peak_workload()
    t0 = time.perf_counter()
    rep = planner.plan(peak_online, peak_offline,
                       candidate_replicas=REPLICA_SWEEP,
                       candidate_blocks=(64, NUM_BLOCKS), slo_target=0.9,
                       duration=DURATION)
    wall = (time.perf_counter() - t0) * 1e6
    out.append(("cluster.plan.min_replicas", wall, str(rep.min_replicas)))
    out.append(("cluster.plan.blocks_per_replica", 0.0,
                str(rep.blocks_per_replica)))
    if rep.offline_throughput is not None:
        out.append(("cluster.plan.offline_tput", 0.0,
                    f"{rep.offline_throughput:.1f}tok/s"))
    for n, nb, att in rep.slo_by_config:
        out.append((f"cluster.plan.slo_{n}rep_{nb}blocks", 0.0, f"{att:.3f}"))
    return out
