"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Figures 6-11 replay the paper's
scenario through the §5.4 simulator (scheduler + KV manager + time model);
estimator accuracy + kernel micro-benches run the real tiny model/kernels;
the roofline rows read the dry-run artifacts (run
``python -m repro.launch.dryrun --all --both-meshes`` first).
"""
from __future__ import annotations

import sys
import time
import traceback


def _section(name, fn, rows_out):
    t0 = time.perf_counter()
    try:
        rows = fn()
    except Exception as e:
        rows = [(f"{name}.ERROR", 0.0, f"{type(e).__name__}:{e}")]
        traceback.print_exc(file=sys.stderr)
    for r in rows:
        rows_out.append(r)
    print(f"# {name}: {time.perf_counter() - t0:.1f}s", file=sys.stderr)


def main() -> None:
    from benchmarks import (ablations, calibration, capacity, cluster,
                            elasticity, estimator_accuracy)
    from benchmarks import figures, kernels_micro, kv_swap, loadgen, roofline

    rows = []
    _section("fig6", figures.fig6_throughput_speedup, rows)
    _section("fig7", figures.fig7_slo, rows)
    _section("fig8", figures.fig8_interplay, rows)
    _section("fig9", figures.fig9_hit_rate, rows)
    _section("fig10", figures.fig10_memory, rows)
    _section("fig11", figures.fig11_trace_prediction, rows)
    _section("estimator", estimator_accuracy.rows, rows)
    _section("calibration", calibration.rows, rows)
    _section("kv_swap", kv_swap.rows, rows)
    _section("capacity", capacity.rows, rows)
    _section("cluster", cluster.rows, rows)
    _section("elasticity", elasticity.rows, rows)
    _section("kernels", kernels_micro.rows, rows)
    _section("ablations", ablations.rows, rows)
    _section("loadgen", loadgen.rows, rows)
    _section("roofline", roofline.rows, rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
