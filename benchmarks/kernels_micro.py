"""Kernel micro-benchmarks: CPU-dispatch wall time + hard numerics gate.

``us_per_call`` times the jitted path the engine actually runs on this
backend (the jnp ref oracles on CPU — what ``impl="auto"`` dispatches to);
on TPU the Pallas kernels compile natively and the same harness times
them. Every row also validates the Pallas kernel(s) for that shape in
interpret mode against ``kernels/ref.py`` — a mismatch is an error, not a
footnote: ``rows()`` raises ``KernelNumericsError`` and the CLI exits
nonzero, so CI cannot go green on silently-wrong kernels.

CLI:
    PYTHONPATH=src:. python benchmarks/kernels_micro.py --json out.json

``benchmarks/check_kernels.py`` gates the JSON against the committed
baseline (``benchmarks/baselines/kernels_micro.json``): per-kernel
``us_per_call`` ceilings plus the ``pallas_matches`` booleans.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention, paged_attention_splitk
from repro.kernels.ssd_scan import ssd_scan

RTOL = ATOL = 2e-4


class KernelNumericsError(AssertionError):
    """A Pallas kernel disagreed with its jnp oracle."""


def _time(fn, reps=10):
    fn()                                    # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def _matches(got, want):
    return bool(np.allclose(np.asarray(got, np.float32),
                            np.asarray(want, np.float32),
                            rtol=RTOL, atol=ATOL))


def rows(strict: bool = True):
    """Returns [(name, us_per_call, "pallas_matches=..."), ...]. With
    ``strict`` (the default — including under ``benchmarks/run.py``), any
    pallas/oracle mismatch raises ``KernelNumericsError`` after all rows
    are measured, naming every offender."""
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    tune = ops.kernel_tuning()
    out = []

    # ---- paged decode: short-context online regime ----------------------
    b, hq, hkv, hd, p, bs, nblk = 8, 8, 2, 64, 64, 16, 16
    q = jax.random.normal(ks[0], (b, hq, hd))
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd))
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd))
    bt = jax.random.randint(ks[3], (b, nblk), 0, p)
    cl = jnp.full((b,), nblk * bs, jnp.int32)
    jit_ref = jax.jit(ref.ref_paged_attention)
    want = jit_ref(q, kp, vp, bt, cl)
    us = _time(lambda: jit_ref(q, kp, vp, bt, cl))
    ok = _matches(paged_attention(q, kp, vp, bt, cl, interpret=True), want)
    out.append(("kernel.paged_attention", us, f"pallas_matches={ok}"))
    ok = _matches(
        paged_attention_splitk(q, kp, vp, bt, cl,
                               pages_per_split=tune.pages_per_split,
                               interpret=True), want)
    out.append(("kernel.paged_attention_splitk", us, f"pallas_matches={ok}"))

    # ---- paged decode: long ragged contexts (the split-K target) --------
    b2, nblk2, p2 = 4, 64, 96
    q2 = jax.random.normal(ks[4], (b2, hq, hd))
    kp2 = jax.random.normal(ks[5], (p2, bs, hkv, hd))
    vp2 = jax.random.normal(ks[6], (p2, bs, hkv, hd))
    bt2 = jax.random.randint(ks[7], (b2, nblk2), 0, p2)
    cl2 = jnp.asarray([nblk2 * bs, 40, 520, 7], jnp.int32)   # ragged batch
    want2 = jit_ref(q2, kp2, vp2, bt2, cl2)
    us = _time(lambda: jit_ref(q2, kp2, vp2, bt2, cl2))
    ok = _matches(
        paged_attention_splitk(q2, kp2, vp2, bt2, cl2,
                               pages_per_split=tune.pages_per_split,
                               interpret=True), want2)
    out.append(("kernel.paged_attention_splitk_long", us,
                f"pallas_matches={ok}"))

    # ---- chunked prefill: fused epilogue, tuned tiles -------------------
    sc, t = 128, 512
    qc = jax.random.normal(ks[4], (sc, hq, hd))
    kc = jax.random.normal(ks[5], (t, hkv, hd))
    vc = jax.random.normal(ks[6], (t, hkv, hd))
    jit_ref2 = jax.jit(ref.ref_chunked_prefill_attention)
    want = jit_ref2(qc, kc, vc, 256)
    us = _time(lambda: jit_ref2(qc, kc, vc, 256))
    ok = _matches(
        chunked_prefill_attention(qc, kc, vc, 256, blk_q=tune.blk_q,
                                  blk_k=tune.blk_k, interpret=True), want)
    out.append(("kernel.chunked_prefill", us, f"pallas_matches={ok}"))

    # ---- chunked prefill: non-divisible chunk/block shapes --------------
    sc3, t3, ctx3 = 100, 420, 250
    q3 = jax.random.normal(ks[0], (sc3, hq, hd))
    k3 = jax.random.normal(ks[1], (t3, hkv, hd))
    v3 = jax.random.normal(ks[2], (t3, hkv, hd))
    want = jit_ref2(q3, k3, v3, ctx3)
    us = _time(lambda: jit_ref2(q3, k3, v3, ctx3))
    ok = _matches(
        chunked_prefill_attention(q3, k3, v3, ctx3, blk_q=tune.blk_q,
                                  blk_k=tune.blk_k, interpret=True), want)
    out.append(("kernel.chunked_prefill_ragged", us, f"pallas_matches={ok}"))

    # ---- SSD chunk scan -------------------------------------------------
    bz, s, h, pd, n = 2, 256, 4, 32, 16
    x = jax.random.normal(ks[7], (bz, s, h, pd))
    dta = -jax.nn.softplus(jax.random.normal(ks[0], (bz, s, h)))
    bm = jax.random.normal(ks[1], (bz, s, n))
    cm = jax.random.normal(ks[2], (bz, s, n))
    jit_ref3 = jax.jit(ref.ref_ssd_sequential)
    yr, fr = jit_ref3(x, dta, bm, cm)
    us = _time(lambda: jit_ref3(x, dta, bm, cm))
    y, fs = ssd_scan(x, dta, bm, cm, chunk=64, interpret=True)
    ok = _matches(y, yr) and _matches(fs, fr)
    out.append(("kernel.ssd_scan", us, f"pallas_matches={ok}"))

    # ---- RG-LRU scan ----------------------------------------------------
    from repro.kernels.rglru_scan import rglru_scan
    a = jax.nn.sigmoid(jax.random.normal(ks[3], (2, 256, 128)))
    bv = jax.random.normal(ks[4], (2, 256, 128))
    jit_ref4 = jax.jit(ref.ref_rglru_scan)
    want = jit_ref4(a, bv)
    us = _time(lambda: jit_ref4(a, bv))
    ok = _matches(rglru_scan(a, bv, chunk=64, interpret=True), want)
    out.append(("kernel.rglru_scan", us, f"pallas_matches={ok}"))

    bad = [name for name, _, d in out if d != "pallas_matches=True"]
    if strict and bad:
        raise KernelNumericsError(
            f"pallas kernels disagree with kernels/ref.py: {', '.join(bad)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write {name: {us_per_call, pallas_matches}} here "
                         "(written even on a numerics failure, for triage)")
    args = ap.parse_args()
    out = rows(strict=False)
    if args.json:
        payload = {name: {"us_per_call": round(us, 1),
                          "pallas_matches": d == "pallas_matches=True"}
                   for name, us, d in out}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
    print("name,us_per_call,derived")
    for name, us, d in out:
        print(f"{name},{us:.1f},{d}")
    bad = [name for name, _, d in out if d != "pallas_matches=True"]
    if bad:
        raise SystemExit(
            f"kernel numerics FAILED: {', '.join(bad)} "
            "(pallas kernel disagrees with kernels/ref.py)")


if __name__ == "__main__":
    main()
