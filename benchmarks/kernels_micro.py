"""Kernel micro-benchmarks: ref-oracle wall time on CPU + structural check
that the Pallas kernels (interpret mode) agree. On TPU the pallas path
compiles natively; us_per_call here is the CPU ref number."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, reps=10):
    fn()                                    # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    out = []

    b, hq, hkv, hd, p, bs, nblk = 8, 8, 2, 64, 64, 16, 16
    q = jax.random.normal(ks[0], (b, hq, hd))
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd))
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd))
    bt = jax.random.randint(ks[3], (b, nblk), 0, p)
    cl = jnp.full((b,), nblk * bs, jnp.int32)
    jit_ref = jax.jit(ref.ref_paged_attention)
    us = _time(lambda: jit_ref(q, kp, vp, bt, cl))
    got = paged_attention(q, kp, vp, bt, cl, interpret=True)
    want = jit_ref(q, kp, vp, bt, cl)
    ok = np.allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    out.append(("kernel.paged_attention", us, f"pallas_matches={ok}"))

    sc, t = 128, 512
    q2 = jax.random.normal(ks[4], (sc, hq, hd))
    k2 = jax.random.normal(ks[5], (t, hkv, hd))
    v2 = jax.random.normal(ks[6], (t, hkv, hd))
    jit_ref2 = jax.jit(ref.ref_chunked_prefill_attention)
    us = _time(lambda: jit_ref2(q2, k2, v2, 256))
    got = chunked_prefill_attention(q2, k2, v2, 256, blk_q=64, blk_k=64,
                                    interpret=True)
    ok = np.allclose(np.asarray(got), np.asarray(jit_ref2(q2, k2, v2, 256)),
                     rtol=2e-4, atol=2e-4)
    out.append(("kernel.chunked_prefill", us, f"pallas_matches={ok}"))

    bz, s, h, pd, n = 2, 256, 4, 32, 16
    x = jax.random.normal(ks[7], (bz, s, h, pd))
    dta = -jax.nn.softplus(jax.random.normal(ks[0], (bz, s, h)))
    bm = jax.random.normal(ks[1], (bz, s, n))
    cm = jax.random.normal(ks[2], (bz, s, n))
    jit_ref3 = jax.jit(ref.ref_ssd_sequential)
    us = _time(lambda: jit_ref3(x, dta, bm, cm))
    y, fs = ssd_scan(x, dta, bm, cm, chunk=64, interpret=True)
    yr, fr = jit_ref3(x, dta, bm, cm)
    ok = np.allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    out.append(("kernel.ssd_scan", us, f"pallas_matches={ok}"))

    from repro.kernels.rglru_scan import rglru_scan
    a = jax.nn.sigmoid(jax.random.normal(ks[3], (2, 256, 128)))
    bv = jax.random.normal(ks[4], (2, 256, 128))
    jit_ref4 = jax.jit(ref.ref_rglru_scan)
    us = _time(lambda: jit_ref4(a, bv))
    got = rglru_scan(a, bv, chunk=64, interpret=True)
    ok = np.allclose(np.asarray(got), np.asarray(jit_ref4(a, bv)),
                     rtol=2e-4, atol=2e-4)
    out.append(("kernel.rglru_scan", us, f"pallas_matches={ok}"))
    return out
