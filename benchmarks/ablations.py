"""Beyond-paper ablations: chunk size, block size, burst-reserve k_sigma —
the §2.1/§5.3 knobs the paper fixes by fiat."""
from __future__ import annotations

from benchmarks.scenario import build_engine
from repro.core import ECHO


def _tput(**kw) -> float:
    eng, online, offline, p = build_engine(ECHO, **kw)
    stats = eng.run(max_iters=200_000, until_time=p["duration"])
    return stats.offline_throughput(), eng.bm.metrics.offline_hit_rate


def rows():
    out = []
    for chunk in (32, 64, 128):
        tput, hit = _tput(chunk_size=chunk, duration=30.0)
        out.append((f"ablation.chunk_{chunk}", 0.0,
                    f"{tput:.1f}tok/s hit={hit:.3f}"))
    for bs in (8, 16, 32):
        tput, hit = _tput(block_size=bs, duration=30.0)
        out.append((f"ablation.block_{bs}", 0.0,
                    f"{tput:.1f}tok/s hit={hit:.3f}"))
    return out
