"""Benchmark-floor gate for CI: compare a fresh ``kv_swap.py --json`` run
against the committed baseline and fail on regression.

The virtual-clock benchmark is deterministic, so in steady state current ==
baseline exactly; the tolerance absorbs intentional-but-small drift from
cost-model tuning without letting a real regression through. Floors only —
improvements always pass (update the committed baseline when they land):

  * offline throughput per mode: current >= baseline * (1 - tolerance)
  * SLO attainment per mode:     current >= baseline - tolerance
  * headline booleans (swap_wins, overlap_wins): must stay True if the
    baseline has them True

On failure the exit message names every violated floor. To accept an
intentional change, regenerate the baseline in-repo:

    PYTHONPATH=src:. python benchmarks/kv_swap.py \
        --json benchmarks/baselines/kv_swap.json
"""
from __future__ import annotations

import argparse
import json
import sys

TPUT_KEY = "offline_throughput"
SLO_KEYS = ("slo_ttft", "slo_tpot")
BOOL_GATES = ("swap_wins", "overlap_wins", "state_swap_wins",
              "recovery_ok", "migration_wins", "autoscale_ok")


def check(current: dict, baseline: dict, tolerance: float,
          obs_tolerance: float = 0.05) -> list:
    """Returns a list of human-readable violations (empty = pass)."""
    violations = []
    for mode, base in baseline.items():
        if mode == "headline":
            continue
        cur = current.get(mode)
        if cur is None:
            violations.append(f"{mode}: missing from current results")
            continue
        floor = base[TPUT_KEY] * (1.0 - tolerance)
        if cur[TPUT_KEY] < floor:
            violations.append(
                f"{mode}.{TPUT_KEY}: {cur[TPUT_KEY]:.1f} < floor "
                f"{floor:.1f} (baseline {base[TPUT_KEY]:.1f} -{tolerance:.0%})")
        for key in SLO_KEYS:
            if cur[key] < base[key] - tolerance:
                violations.append(
                    f"{mode}.{key}: {cur[key]:.3f} < floor "
                    f"{base[key] - tolerance:.3f} (baseline {base[key]:.3f})")
    base_head = baseline.get("headline", {})
    cur_head = current.get("headline", {})
    for gate in BOOL_GATES:
        if base_head.get(gate) and not cur_head.get(gate):
            violations.append(f"headline.{gate}: regressed True -> False")
    # observability must stay near-free: instrumented/bare wall ratio of the
    # swap mode (gated whenever the current run measured it — no baseline
    # entry needed, the ceiling is absolute)
    obs = cur_head.get("obs_overhead")
    if obs is not None and obs > 1.0 + obs_tolerance:
        violations.append(
            f"headline.obs_overhead: x{obs:.3f} > ceiling "
            f"x{1.0 + obs_tolerance:.2f} (tracing+metrics must cost "
            f"<= {obs_tolerance:.0%} wall time)")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="JSON from a fresh benchmarks/kv_swap.py --json run")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/kv_swap.json",
                    help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative throughput / absolute SLO slack (0.10)")
    ap.add_argument("--obs-tolerance", type=float, default=0.05,
                    help="max fractional wall-time overhead of the "
                         "instrumented run over the bare one (0.05)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    violations = check(current, baseline, args.tolerance,
                       obs_tolerance=args.obs_tolerance)
    if violations:
        print("benchmark floor violated:", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        print("if intentional, refresh the baseline:\n"
              "  PYTHONPATH=src:. python benchmarks/kv_swap.py "
              "--json benchmarks/baselines/kv_swap.json", file=sys.stderr)
        raise SystemExit(1)
    modes = [m for m in baseline if m != "headline"]
    print(f"benchmark floor ok: {', '.join(modes)} within "
          f"{args.tolerance:.0%} of baseline")


if __name__ == "__main__":
    main()
