"""Shared benchmark scenario (§7.1): A100-magnitude time model, bursty
online trace (ShareGPT-like), LooGLE-like offline corpus whose prefix
working set exceeds the KV cache — the regime where scheduling and cache
policy matter."""
from __future__ import annotations

from repro.core import SLO, EchoEngine, PolicyConfig, TimeModel
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.serving import EchoService

# LooGLE-like regime (§7.1): the offline prefix working set (10 docs x 20
# blocks = 200) fits the 256-block cache, but online bursts flush it under
# LRU — the setting of Fig. 9 where the task-aware manager pays off.
DEFAULTS = dict(
    num_blocks=256, block_size=16, chunk_size=64, max_running=48,
    host_kv_blocks=0,                 # host swap tier off unless asked
    duration=60.0,
    online_rate=1.5, burst_rate=8.0, burst_len=8.0, burst_prob=0.05,
    online_prompt=160, online_new=24, slo=SLO(1.0, 0.1),
    n_docs=10, questions=96, doc_len=320, question_len=32, offline_new=16,
    io_spec=None,                     # block I/O family (None = paged KV)
)


def time_model(**kw) -> TimeModel:
    """A100-magnitude Eq.6-8 coefficients (micro-benchmark-shaped; see
    estimator_accuracy)."""
    return TimeModel.a100(**kw)


def build_scenario(seed: int = 0, tm_kw=None, **overrides):
    """Workload + parameters of the shared §7.1 scenario."""
    p = dict(DEFAULTS)
    p.update(overrides)
    tm = time_model(**(tm_kw or {}))
    trace = BurstyTrace(base_rate=p["online_rate"],
                        tidal_period=2 * p["duration"],
                        burst_rate=p["burst_rate"], burst_len=p["burst_len"],
                        burst_prob=p["burst_prob"], seed=seed + 10)
    arrivals = trace.sample(0, p["duration"])
    online = make_online_requests(arrivals, prompt_mean=p["online_prompt"],
                                  prompt_std=p["online_prompt"] // 4,
                                  max_new_mean=p["online_new"],
                                  slo=p["slo"], seed=seed + 20)
    offline = make_offline_corpus(p["n_docs"], p["questions"],
                                  doc_len=p["doc_len"],
                                  question_len=p["question_len"],
                                  max_new=p["offline_new"], seed=seed + 30)
    return tm, online, offline, p


def _make_engine(policy, tm, p, clock_model):
    return EchoEngine(None, None, policy, num_blocks=p["num_blocks"],
                      block_size=p["block_size"], chunk_size=p["chunk_size"],
                      time_model=tm, clock_model=clock_model,
                      max_running=p["max_running"],
                      host_kv_blocks=p["host_kv_blocks"],
                      io_spec=p["io_spec"])


def build_service(policy: PolicyConfig, seed: int = 0, tm_kw=None,
                  clock_model=None, admission=None, **overrides):
    """The scenario behind the one serving API: an ``EchoService`` over a
    virtual-clock engine with the workload already registered (handles and
    events live on the service). With ``admission=None`` ``service.drive``
    delegates to the legacy run loop, keeping the exact trace numbers."""
    tm, online, offline, p = build_scenario(seed=seed, tm_kw=tm_kw,
                                            **overrides)
    service = EchoService(_make_engine(policy, tm, p, clock_model),
                          admission=admission)
    for r in online + offline:
        service.submit_request(r)
    return service, online, offline, p


def build_engine(policy: PolicyConfig, seed: int = 0, tm_kw=None,
                 clock_model=None, **overrides):
    """Legacy entry point: a bare engine with the workload pre-submitted —
    no serving layer attached, so ``eng.run()`` callers retain nothing."""
    tm, online, offline, p = build_scenario(seed=seed, tm_kw=tm_kw,
                                            **overrides)
    eng = _make_engine(policy, tm, p, clock_model)
    for r in online + offline:
        eng.submit(r)
    return eng, online, offline, p
