"""Roofline report from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and prints per (arch x shape x mesh):
compute / memory / collective seconds, dominant term, MODEL_FLOPS ratio.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def rows(single_pod_only: bool = True):
    out = []
    recs = load_records("pod16x16" if single_pod_only else None)
    for r in recs:
        if not r.get("ok") or "roofline" not in r:
            out.append((f"roofline.{r['arch']}.{r['shape']}", 0.0, "FAIL"))
            continue
        rf = r["roofline"]
        out.append((
            f"roofline.{r['arch']}.{r['shape']}",
            rf[rf["dominant"]] * 1e6,       # dominant term in us
            f"c={rf['compute_s']:.3e}s m={rf['memory_s']:.3e}s "
            f"x={rf['collective_s']:.3e}s dom={rf['dominant'][:-2]} "
            f"useful={r.get('useful_ratio', float('nan')):.2f}",
        ))
    return out


def table() -> str:
    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "MODEL/HLO flops |", "|---|---|---|---|---|---|---|"]
    for r in load_records("pod16x16"):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"{rf['dominant'][:-2]} | {r.get('useful_ratio', 0):.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
