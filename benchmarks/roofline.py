"""Roofline report from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and prints per (arch x shape x mesh):
compute / memory / collective seconds, dominant term, MODEL_FLOPS ratio.

CLI: ``python benchmarks/roofline.py [--json out.json]`` — the JSON mode
(what CI uploads as an artifact) carries the raw dry-run records plus the
summary table rows.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def rows(single_pod_only: bool = True):
    out = []
    recs = load_records("pod16x16" if single_pod_only else None)
    for r in recs:
        if not r.get("ok") or "roofline" not in r:
            out.append((f"roofline.{r['arch']}.{r['shape']}", 0.0, "FAIL"))
            continue
        rf = r["roofline"]
        out.append((
            f"roofline.{r['arch']}.{r['shape']}",
            rf[rf["dominant"]] * 1e6,       # dominant term in us
            f"c={rf['compute_s']:.3e}s m={rf['memory_s']:.3e}s "
            f"x={rf['collective_s']:.3e}s dom={rf['dominant'][:-2]} "
            f"useful={r.get('useful_ratio', float('nan')):.2f}",
        ))
    return out


def table() -> str:
    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "MODEL/HLO flops |", "|---|---|---|---|---|---|---|"]
    for r in load_records("pod16x16"):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"{rf['dominant'][:-2]} | {r.get('useful_ratio', 0):.2f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write {records, rows} from the dry-run artifacts")
    ap.add_argument("--mesh", default=None,
                    help="filter records by mesh name (e.g. pod16x16)")
    args = ap.parse_args()
    if args.json:
        recs = load_records(args.mesh)
        payload = {
            "records": recs,
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows(single_pod_only=False)],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        ok = sum(1 for r in recs if r.get("ok"))
        print(f"roofline: {ok}/{len(recs)} dry-run records ok -> {args.json}")
        if ok < len(recs):
            raise SystemExit(1)
    print(table())


if __name__ == "__main__":
    main()
