import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

EXPECTED = {
    "qwen2-vl-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                         num_kv_heads=8, d_ff=29568, vocab_size=152064),
    "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120, num_heads=40,
                                  num_kv_heads=8, d_ff=8192,
                                  vocab_size=202048, num_experts=16, top_k=1),
    "qwen3-4b": dict(num_layers=36, d_model=2560, num_heads=32,
                     num_kv_heads=8, d_ff=9728, vocab_size=151936,
                     qk_norm=True),
    "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                              num_kv_heads=4, d_ff=768, vocab_size=151936,
                              num_experts=128, top_k=8),
    "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab_size=50280,
                        ssm_state=128),
    "yi-9b": dict(num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
                  d_ff=11008, vocab_size=64000),
    "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                            num_kv_heads=24, d_ff=6144, vocab_size=2048),
    "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                        num_kv_heads=1, d_ff=24576, vocab_size=49152),
    "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                           num_kv_heads=32, d_ff=13440, vocab_size=92416),
    "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288, vocab_size=256000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_dims(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_invariants(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.dtype == "float32"


def test_param_counts_plausible():
    # within 35% of the named sizes (arch-level approximations allowed)
    approx = {"qwen2-vl-72b": 72e9, "qwen3-4b": 4e9, "mamba2-1.3b": 1.3e9,
              "yi-9b": 8.8e9, "codeqwen1.5-7b": 7.2e9,
              "qwen3-moe-30b-a3b": 30e9}
    for arch, n in approx.items():
        got = get_config(arch).param_count
        assert 0.65 * n < got < 1.45 * n, (arch, got)
