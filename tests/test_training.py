"""Training substrate: optimizer, schedule, pipeline, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import adamw_init, adamw_update, cosine_lr, make_train_step
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream


def test_loss_decreases(tiny_model):
    model, params = tiny_model
    step = jax.jit(make_train_step(model, total_steps=30))
    opt = adamw_init(params)
    stream = TokenStream(model.cfg.vocab_size, seed=0)
    losses = []
    for i, b in enumerate(stream.batches(4, 32)):
        params, opt, m = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        if i >= 14:
            break
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_cosine_lr_shape():
    assert float(cosine_lr(0, peak=1e-3, warmup=10, total=100)) < 1e-3
    peak = float(cosine_lr(10, peak=1e-3, warmup=10, total=100))
    assert abs(peak - 1e-3) / 1e-3 < 0.15
    end = float(cosine_lr(100, peak=1e-3, warmup=10, total=100))
    assert end < 0.2 * 1e-3


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    opt = adamw_init(params)
    new, opt, gnorm = adamw_update(params, grads, opt, lr=1e-3, clip=1.0)
    assert float(gnorm) > 1e5
    assert float(jnp.max(jnp.abs(new["w"] - params["w"]))) < 0.1


def test_checkpoint_roundtrip(tiny_model):
    model, params = tiny_model
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck")
        ckpt.save(p, params, step=7)
        restored, step = ckpt.restore(p, params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_token_stream_deterministic():
    s1 = TokenStream(128, seed=5)
    s2 = TokenStream(128, seed=5)
    b1 = next(iter(s1.batches(2, 16)))
    b2 = next(iter(s2.batches(2, 16)))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
