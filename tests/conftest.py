import jax
import pytest

from repro.configs.base import ModelConfig

# NOTE: no XLA_FLAGS here — tests and benches see 1 device; only
# launch/dryrun.py forces 512 host devices (and only in its own process).


@pytest.fixture(scope="session")
def tiny_cfg():
    return ModelConfig(
        name="tiny-dense", family="dense", source="test",
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        dtype="float32", rope_theta=10_000.0)


@pytest.fixture(scope="session")
def tiny_model(tiny_cfg):
    from repro.models import Model
    m = Model(tiny_cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params
