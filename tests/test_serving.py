"""EchoService facade: API equivalence with the legacy submit_all+run path,
streaming token events, mid-flight cancellation with zero leaked blocks,
and admission-control backpressure — on engine and cluster backends."""
import numpy as np

from repro.cluster import ClusterSimulator
from repro.core import (ECHO, SLO, EchoEngine, Request, RequestState,
                        TaskType, TimeModel)
from repro.core.simulator import clone_requests
from repro.data import make_offline_corpus, make_online_requests
from repro.serving import AdmissionConfig, EchoService, HandleStatus

TM_KW = dict()


def _tm():
    return TimeModel.a100()


def _workload(seed=0, duration=6.0, rate=2.0):
    rng = np.random.default_rng(seed)
    arrivals = list(np.cumsum(rng.exponential(1.0 / rate, int(rate * duration))))
    online = make_online_requests(arrivals, prompt_mean=48, prompt_std=12,
                                  max_new_mean=8, slo=SLO(1.0, 0.1),
                                  seed=seed + 1)
    offline = make_offline_corpus(3, 8, doc_len=96, question_len=16,
                                  max_new=6, seed=seed + 2)
    return online, offline


def _engine(num_blocks=128, **kw):
    return EchoEngine(None, None, ECHO, num_blocks=num_blocks, block_size=16,
                      chunk_size=32, time_model=_tm(), **kw)


def assert_no_block_leaks(engine):
    """Every referenced block must be owned by a live running request, and
    a drained engine must hold no references at all."""
    owned = set()
    for r in engine.scheduler.running:
        owned.update(r.block_ids)
    for b in engine.bm.blocks:
        if b.ref > 0:
            assert b.bid in owned, f"block {b.bid} referenced but unowned"
    # free-list + cached + running must account for every block
    n_free = engine.bm.free_blocks
    n_cached = engine.bm.cached_blocks
    n_running = engine.bm.running_blocks
    assert n_free + n_cached + n_running == engine.bm.num_blocks
    # host tier, when present, must stay within capacity and never hold a
    # hash that is also device-resident (the device copy shadows it)
    host = engine.bm.host
    if host is not None:
        assert len(host) <= host.capacity
        for h in host.blocks:
            assert h not in engine.bm.hash_to_bid, \
                f"hash {h} resident on BOTH tiers"


def assert_no_owner_pin_leaks(engine):
    """On a drained engine (every request terminal) no block on either tier
    may still carry an unfinished-owner pin — preempted owners either came
    back (pin consumed) or went terminal (pin released)."""
    for b in engine.bm.blocks:
        assert b.unfinished_owners == 0, \
            f"device block {b.bid} pinned by a dead owner"
    if engine.bm.host is not None:
        for hb in engine.bm.host.blocks.values():
            assert hb.unfinished_owners == 0, \
                f"host block hash {hb.hash} pinned by a dead owner"


# --------------------------------------------------------------- equivalence
def test_drive_matches_legacy_engine():
    online, offline = _workload()
    legacy = _engine()
    for r in clone_requests(online + offline, preserve_rid=True):
        legacy.submit(r)
    want = legacy.run(max_iters=20_000, until_time=60.0)

    service = EchoService(_engine())
    got = service.drive(clone_requests(online + offline, preserve_rid=True),
                        max_iters=20_000, until_time=60.0)
    assert len(got.finished) == len(want.finished)
    assert got.offline_throughput() == want.offline_throughput()
    assert got.slo_attainment("ttft") == want.slo_attainment("ttft")
    assert got.slo_attainment("tpot") == want.slo_attainment("tpot")


def test_drive_matches_legacy_cluster():
    online, offline = _workload(seed=7, duration=8.0, rate=3.0)

    def sim():
        return ClusterSimulator(3, ECHO, num_blocks=96, time_model=_tm(),
                                seed=0)

    legacy = sim()
    legacy.submit_all(clone_requests(online + offline, preserve_rid=True))
    want = legacy.run(until_time=60.0)

    service = EchoService(sim())
    got = service.drive(clone_requests(online + offline, preserve_rid=True),
                        until_time=60.0)
    assert got.finished_counts() == want.finished_counts()
    assert got.offline_throughput() == want.offline_throughput()
    assert got.slo_attainment("ttft") == want.slo_attainment("ttft")
    assert got.slo_attainment("tpot") == want.slo_attainment("tpot")


def test_live_metrics_match_post_hoc_stats():
    online, offline = _workload(seed=3)
    service = EchoService(_engine())
    stats = service.drive(clone_requests(online + offline), max_iters=20_000)
    live = service.live
    on_done = sum(1 for r in stats.finished if r.is_online)
    assert live.finished_online == on_done
    assert live.finished_offline == len(stats.finished) - on_done
    assert live.slo_attainment("ttft") == stats.slo_attainment("ttft")
    assert live.slo_attainment("tpot") == stats.slo_attainment("tpot")


# ----------------------------------------------------------------- streaming
def test_streaming_token_events_arrive_before_final_iteration():
    online, offline = _workload(seed=11)
    eng = _engine()
    service = EchoService(eng)
    seen_at_iter = []
    service.events.on_token(
        lambda ev: seen_at_iter.append(len(eng.stats.iterations)))
    service.drive(clone_requests(online + offline), max_iters=20_000)
    total = len(eng.stats.iterations)
    assert seen_at_iter, "no token events fired"
    assert seen_at_iter[0] < total - 1, \
        "first token event must precede the final iteration"


def test_handle_tokens_generator_streams_incrementally():
    service = EchoService(_engine())
    h = service.submit(tuple(range(40)), task_type="online",
                       max_new_tokens=6, slo=SLO(1.0, 0.1), arrival_time=0.0)
    doc = tuple(range(200, 280))
    for i in range(3):
        service.submit(doc + tuple(range(300 + 8 * i, 306 + 8 * i)),
                       task_type="offline", max_new_tokens=4)
    got = []
    for ev in h.tokens():
        got.append(ev.token)
        assert ev.handle is h
        assert ev.index == len(got) - 1
        # mid-stream the offline work is still outstanding: streaming
        # interleaves with scheduling rather than waiting for a drain
        if ev.first:
            assert service.backend.has_work()
    assert got == list(h.request.output_tokens)
    assert h.status is HandleStatus.FINISHED
    assert h.ttft() is not None
    service.run()          # drain the offline remainder


def test_first_token_and_finish_events():
    service = EchoService(_engine())
    firsts, finishes = [], []
    service.events.on_first_token(lambda ev: firsts.append(ev.handle.rid))
    service.events.on_finish(lambda hd: finishes.append(hd.rid))
    hs = [service.submit(tuple(range(i * 7, i * 7 + 30)),
                         task_type="offline", max_new_tokens=3)
          for i in range(3)]
    service.run()
    assert sorted(firsts) == sorted(h.rid for h in hs)
    assert sorted(finishes) == sorted(h.rid for h in hs)
    assert all(h.status is HandleStatus.FINISHED for h in hs)


# --------------------------------------------------------------- cancellation
def test_abort_running_online_request_frees_blocks():
    service = EchoService(_engine(num_blocks=96))
    target = service.submit(tuple(range(64)), task_type="online",
                            max_new_tokens=50, slo=SLO(5.0, 1.0),
                            arrival_time=0.0)
    rest = [service.submit(tuple(range(100 + i * 40, 148 + i * 40)),
                           task_type="offline", max_new_tokens=4)
            for i in range(3)]
    # run until the target is mid-decode (running, holding blocks)
    for ev in target.tokens():
        if ev.index >= 2:
            break
    assert target.status is HandleStatus.RUNNING
    assert target.request.block_ids, "target should hold KV blocks"
    eng = service.engine

    assert target.abort()
    assert target.status is HandleStatus.ABORTED
    assert target.request.block_ids == [], "abort must release all blocks"
    assert target.request not in eng.scheduler.running
    assert_no_block_leaks(eng)
    assert not target.abort(), "double-abort must be a no-op"

    # scheduler still makes progress: remaining offline work completes
    stats = service.run()
    assert all(h.status is HandleStatus.FINISHED for h in rest)
    assert target.request not in stats.finished
    assert target.request in stats.aborted
    assert_no_block_leaks(eng)
    assert eng.bm.running_blocks == 0


def test_abort_preempted_offline_request_drops_pool_pins():
    # tiny cache + an online burst forces offline preemption (recompute
    # mode: the victim returns to the OfflinePool)
    eng = _engine(num_blocks=20)
    service = EchoService(eng)
    doc = tuple(range(500, 596))
    offs = [service.submit(doc + tuple(range(700 + 9 * i, 708 + 9 * i)),
                           task_type="offline", max_new_tokens=40)
            for i in range(2)]
    onl = [service.submit(tuple(range(i * 70, i * 70 + 60)),
                          task_type="online", max_new_tokens=12,
                          slo=SLO(10.0, 1.0), arrival_time=0.01 * (i + 1))
           for i in range(3)]
    preempted = []
    service.events.on_preempt(lambda hd: preempted.append(hd))
    for _ in range(400):
        victim = next((h for h in offs
                       if h.status is HandleStatus.PREEMPTED), None)
        if victim is not None:
            break
        if not service.step():
            break
    assert victim is not None, "no offline request was preempted"
    assert preempted, "preempt event must fire"
    assert victim.request in eng.pool

    chain = eng.pool._chains[victim.request.rid]
    rc_before = [eng.pool.rc(h) for h in chain]
    assert victim.abort()
    assert victim.request not in eng.pool
    for h, before in zip(chain, rc_before):
        assert eng.pool.rc(h) == before - 1, "radix-pool pin not dropped"
    assert victim.request.block_ids == []
    assert_no_block_leaks(eng)

    service.run()
    for h in onl + [o for o in offs if o is not victim]:
        assert h.status is HandleStatus.FINISHED, h
    assert eng.bm.running_blocks == 0
    assert_no_block_leaks(eng)


def test_abort_queued_request_before_start():
    service = EchoService(_engine())
    h = service.submit(tuple(range(30)), task_type="online",
                       max_new_tokens=4, slo=SLO(1.0, 0.1),
                       arrival_time=100.0)          # far future
    assert h.status is HandleStatus.QUEUED
    assert h.abort()
    assert h.status is HandleStatus.ABORTED
    assert h.result().tokens == []


def test_abort_on_cluster_backend():
    sim = ClusterSimulator(2, ECHO, num_blocks=64, time_model=_tm(), seed=0)
    service = EchoService(sim)
    hs = [service.submit(tuple(range(i * 30, i * 30 + 40)),
                         task_type="offline", max_new_tokens=30)
          for i in range(4)]
    for _ in range(6):
        service.step()
    victim = next((h for h in hs if h.status is HandleStatus.RUNNING), hs[0])
    assert victim.abort()
    assert victim.status is HandleStatus.ABORTED
    service.run()
    for eng in service.backend.engines():
        assert_no_block_leaks(eng)
        assert eng.bm.running_blocks == 0
    done = [h for h in hs if h is not victim]
    assert all(h.status is HandleStatus.FINISHED for h in done)


# ----------------------------------------------------------------- admission
def test_bounded_online_queue_sheds():
    service = EchoService(_engine(),
                          admission=AdmissionConfig(max_online_queue=2))
    shed = []
    service.events.on_shed(lambda hd: shed.append(hd))
    hs = [service.submit(tuple(range(i, i + 30)), task_type="online",
                         max_new_tokens=3, slo=SLO(1.0, 0.1),
                         arrival_time=0.0)
          for i in range(6)]
    statuses = [h.status for h in hs]
    assert statuses.count(HandleStatus.SHED) == 4
    assert len(shed) == 4
    service.run()
    assert sum(1 for h in hs if h.status is HandleStatus.FINISHED) == 2
    assert service.live.shed == 4


def test_slo_infeasible_arrival_is_shed():
    service = EchoService(
        _engine(), admission=AdmissionConfig(slo_shed_factor=1.0))
    # impossibly tight TTFT: the TimeModel alone predicts a miss
    h = service.submit(tuple(range(512)), task_type="online",
                       max_new_tokens=4, slo=SLO(ttft=1e-6, tpot=0.1),
                       arrival_time=0.0)
    assert h.status is HandleStatus.SHED
    # a feasible one still gets through
    ok = service.submit(tuple(range(40)), task_type="online",
                        max_new_tokens=4, slo=SLO(10.0, 1.0),
                        arrival_time=0.0)
    assert ok.status is HandleStatus.QUEUED
    service.run()
    assert ok.status is HandleStatus.FINISHED


def test_offline_soft_cap_defers_and_feeds():
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=2))
    hs = [service.submit(tuple(range(i * 31, i * 31 + 40)),
                         task_type="offline", max_new_tokens=3)
          for i in range(5)]
    deferred = [h for h in hs if h._deferred]
    assert len(deferred) == 3, "work beyond the soft cap must be deferred"
    assert all(h.status is HandleStatus.QUEUED for h in deferred)
    assert service.backend.offline_backlog() == 2
    service.run()
    assert all(h.status is HandleStatus.FINISHED for h in hs), \
        "deferred work must eventually be fed and complete"


def test_abort_deferred_offline_request():
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=1))
    h1 = service.submit(tuple(range(40)), task_type="offline", max_new_tokens=3)
    h2 = service.submit(tuple(range(50, 90)), task_type="offline",
                        max_new_tokens=3)
    assert h2._deferred
    assert h2.abort()
    assert h2.status is HandleStatus.ABORTED
    service.run()
    assert h1.status is HandleStatus.FINISHED
    assert h2.result().tokens == []


def test_pump_never_resubmits_aborted_deferred_handle():
    """Regression: ``pump`` used to resubmit deferred handles blindly — a
    handle aborted while deferred could be resurrected into the backend."""
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=1))
    hs = [service.submit(tuple(range(i * 37, i * 37 + 40)),
                         task_type="offline", max_new_tokens=3)
          for i in range(4)]
    deferred = [h for h in hs if h._deferred]
    assert len(deferred) == 3
    victim = deferred[1]
    # simulate a handle that went terminal while still in the overflow
    # queue without the controller hearing about it (no cancel() call)
    victim._aborted = True
    kept = [h for h in hs if h is not victim]
    service.run()
    assert all(h.status is HandleStatus.FINISHED for h in kept)
    assert victim.request.state not in (RequestState.FINISHED,
                                        RequestState.RUNNING)
    assert victim.request not in service.engine.pool


def test_pump_emits_requeue_events():
    """Every deferred->queued transition must be observable: LiveMetrics
    used to undercount them because pump bypassed the event bus."""
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=1))
    requeued = []
    service.events.on_requeue(lambda hd: requeued.append(hd.rid))
    hs = [service.submit(tuple(range(i * 37, i * 37 + 40)),
                         task_type="offline", max_new_tokens=3)
          for i in range(4)]
    n_deferred = sum(1 for h in hs if h._deferred)
    assert n_deferred == 3
    service.run()
    assert all(h.status is HandleStatus.FINISHED for h in hs)
    assert len(requeued) == n_deferred
    assert service.live.requeued == n_deferred
    assert service.admission.requeued_total == n_deferred


def test_pump_preserves_deferred_fifo_order():
    """A saturated cap must not rotate the overflow queue: deferred work
    drains in submission order once capacity frees."""
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=1))
    requeued = []
    service.events.on_requeue(lambda hd: requeued.append(hd.rid))
    hs = [service.submit(tuple(range(i * 37, i * 37 + 40)),
                         task_type="offline", max_new_tokens=3)
          for i in range(5)]
    deferred_order = [h.rid for h in hs if h._deferred]
    assert len(deferred_order) == 4
    service.run()
    assert requeued == deferred_order, \
        "deferred work must be admitted FIFO, not rotated"


def test_cancel_deferred_is_tombstoned_not_scanned():
    service = EchoService(
        _engine(), admission=AdmissionConfig(offline_pool_cap=1))
    hs = [service.submit(tuple(range(i * 37, i * 37 + 40)),
                         task_type="offline", max_new_tokens=3)
          for i in range(5)]
    deferred = [h for h in hs if h._deferred]
    victim = deferred[2]
    assert victim.abort()
    # the deque entry survives as a tombstone until pump sweeps it
    assert victim.rid in service.admission._tombstones
    assert not service.admission.cancel(victim), "double-cancel must fail"
    service.run()
    assert victim.status is HandleStatus.ABORTED
    assert not service.admission._tombstones, "tombstone must be swept"
    others = [h for h in hs if h is not victim]
    assert all(h.status is HandleStatus.FINISHED for h in others)


def test_trace_replay_admission_judges_at_arrival_time():
    """Regression: driving a pre-generated trace through admission must
    judge each request when the clock REACHES its arrival, not against the
    t=0 queue at submit time — otherwise a bounded queue sheds nearly the
    whole trace."""
    # 12 online arrivals spread 0.5s apart: never more than one waiting
    online = make_online_requests([0.5 * i for i in range(12)],
                                  prompt_mean=40, prompt_std=8,
                                  max_new_mean=4, slo=SLO(1.0, 0.1), seed=5)
    service = EchoService(_engine(),
                          admission=AdmissionConfig(max_online_queue=2))
    stats = service.drive(clone_requests(online), max_iters=20_000)
    assert service.live.shed == 0, \
        "spread-out arrivals must not be shed by a bounded queue"
    assert len(stats.finished) == len(online)

    # same trace collapsed onto t=0 *is* shed beyond the bound
    squeezed = clone_requests(online)
    for r in squeezed:
        r.arrival_time = 0.0
    service2 = EchoService(_engine(),
                           admission=AdmissionConfig(max_online_queue=2))
    service2.drive(squeezed, max_iters=20_000)
    assert service2.live.shed == len(online) - 2


def test_inactive_admission_config_is_passthrough():
    """Regression: a present-but-gateless AdmissionConfig must behave like
    no admission at all — future-dated requests must not be held forever."""
    online, offline = _workload(seed=13)
    service = EchoService(_engine(), admission=AdmissionConfig())
    stats = service.drive(clone_requests(online + offline), max_iters=20_000)
    assert len(stats.finished) == len(online) + len(offline)
    assert not service._held


def test_shed_on_idle_release_does_not_strand_later_arrivals():
    """Regression: when an idle backend force-releases a held arrival that
    gets shed (nothing submitted), later held arrivals must still be judged
    and served rather than stranded."""
    service = EchoService(
        _engine(), admission=AdmissionConfig(slo_shed_factor=1.0))
    bad = service.submit(tuple(range(600)), task_type="online",
                         max_new_tokens=2, slo=SLO(1e-6, 0.1),
                         arrival_time=1.0)
    good = [service.submit(tuple(range(i * 40, i * 40 + 30)),
                           task_type="online", max_new_tokens=2,
                           slo=SLO(10.0, 1.0), arrival_time=2.0 + i)
            for i in range(3)]
    service.run()
    assert bad.status is HandleStatus.SHED
    assert all(h.status is HandleStatus.FINISHED for h in good)


def test_terminal_handles_are_evicted_from_service():
    service = EchoService(_engine())
    hs = [service.submit(tuple(range(i * 9, i * 9 + 20)),
                         task_type="offline", max_new_tokens=2)
          for i in range(3)]
    assert len(service.handles) == 3
    service.run()
    assert all(h.status is HandleStatus.FINISHED for h in hs)
    assert not service.handles, "terminal handles must be evicted"


def test_cluster_undispatched_abort_is_counted():
    sim = ClusterSimulator(2, ECHO, num_blocks=64, time_model=_tm(), seed=0)
    service = EchoService(sim)
    h = service.submit(tuple(range(30)), task_type="offline",
                       max_new_tokens=2, arrival_time=50.0)
    assert h.abort()                       # still in the cluster arrival heap
    stats = service.stats()
    assert h.request in stats.merged().aborted
    assert service.live.aborted == 1


def test_abort_held_future_arrival():
    service = EchoService(_engine(),
                          admission=AdmissionConfig(max_online_queue=8))
    h = service.submit(tuple(range(30)), task_type="online",
                       max_new_tokens=3, slo=SLO(1.0, 0.1), arrival_time=9.0)
    assert h.status is HandleStatus.QUEUED and h._deferred
    assert h.abort()
    assert h.status is HandleStatus.ABORTED
    assert not service._held


# --------------------------------------------------------------- intake order
def test_engine_submit_keeps_pending_sorted():
    eng = _engine()
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=(1, 2, 3), max_new_tokens=1,
                    task_type=TaskType.OFFLINE,
                    arrival_time=float(t))
            for t in rng.uniform(0, 10, 50)]
    for r in reqs:
        eng.submit(r)
    keys = [(r.arrival_time, r.rid) for r in eng.pending]
    assert keys == sorted(keys)
    # _pull_arrivals drains in order (micro-assert inside must not fire)
    eng.now = 20.0
    eng._pull_arrivals()
    assert not eng.pending


def test_service_status_reflects_lifecycle():
    service = EchoService(_engine())
    h = service.submit(tuple(range(40)), task_type="online",
                       max_new_tokens=3, slo=SLO(1.0, 0.1), arrival_time=0.0)
    assert h.status is HandleStatus.QUEUED
    service.step()
    assert h.status in (HandleStatus.RUNNING, HandleStatus.FINISHED)
    service.run()
    assert h.status is HandleStatus.FINISHED
    assert h.request.state == RequestState.FINISHED
