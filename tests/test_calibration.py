"""§5 calibration loop: ground-truth clock vs. estimate split, perturbed
clocks, drift-triggered refits, convergence, and heterogeneous fleets."""
import numpy as np

from repro.core import (ECHO, ECHO_C, SLO, EchoEngine, OnlineCalibrator,
                        TimeModel)
from repro.data import make_offline_corpus, make_online_requests


def _rand_batch(rng):
    """A plausible iteration shape: chunks mid-context + a decode batch."""
    spans = []
    if rng.random() < 0.7:
        s = int(rng.integers(0, 512))
        spans.append((s, s + int(rng.integers(16, 128))))
    lens = [int(x) for x in rng.integers(32, 512, rng.integers(0, 12))]
    if not spans and not lens:
        lens = [64]
    return spans, lens


def _feed(cal, truth, n, rng, t0=0.0):
    t = t0
    for _ in range(n):
        spans, lens = _rand_batch(rng)
        obs = truth.batch_time(spans, lens)
        t += obs
        cal.observe(t, spans, lens, obs)
    return t


# ------------------------------------------------------------- presets
def test_hw_presets_and_perturbation():
    a, h = TimeModel.a100(), TimeModel.h100()
    spans, lens = [(0, 256)], [128, 256]
    assert h.batch_time(spans, lens) < a.batch_time(spans, lens)
    assert TimeModel.preset("h100").gamma == h.gamma

    p = TimeModel.a100().perturbed(scale=2.0, jitter=0.0, seed=0)
    assert np.isclose(p.batch_time(spans, lens),
                      2.0 * a.batch_time(spans, lens))
    # seeded jitter: deterministic across instances, noisy across calls
    p1 = TimeModel.a100().perturbed(scale=1.0, jitter=0.1, seed=3)
    p2 = TimeModel.a100().perturbed(scale=1.0, jitter=0.1, seed=3)
    seq1 = [p1.batch_time(spans, lens) for _ in range(4)]
    seq2 = [p2.batch_time(spans, lens) for _ in range(4)]
    assert seq1 == seq2
    assert len(set(seq1)) > 1


def test_fit_prefill_accepts_span_samples():
    true = TimeModel(alpha=3e-8, beta=2e-6, c=1e-6)
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(20):
        s = int(rng.integers(0, 2048))
        e = s + int(rng.integers(32, 1024))
        samples.append(((s, e), true.prefill_time([(s, e)])))
    tm = TimeModel()
    tm.fit_prefill(samples)
    for span in ((0, 1000), (500, 700)):
        want = true.prefill_time([span])
        assert abs(tm.prefill_time([span]) - want) / want < 0.1


# ------------------------------------------------------------- calibrator
def test_calibrator_converges_on_synthetic_drift():
    tm = TimeModel.a100()
    truth = TimeModel.a100().perturbed(scale=2.0, jitter=0.02, seed=1)
    cal = OnlineCalibrator(tm)
    _feed(cal, truth, 400, np.random.default_rng(2))
    assert cal.refits >= 1
    assert cal.mean_rel_err(100) < 0.1, cal.mean_rel_err(100)


def test_no_refit_under_stable_load():
    tm = TimeModel.a100()
    cal = OnlineCalibrator(tm)
    _feed(cal, tm, 300, np.random.default_rng(3))   # truth == estimate
    assert cal.refits == 0
    assert cal.mean_rel_err() < 1e-9


def test_drift_triggered_refit_after_shift():
    tm = TimeModel.a100()
    cal = OnlineCalibrator(tm)
    rng = np.random.default_rng(4)
    t = _feed(cal, TimeModel.a100(), 100, rng)      # stable: no refits
    assert cal.refits == 0
    truth = TimeModel.a100().perturbed(scale=1.6, jitter=0.01, seed=5)
    _feed(cal, truth, 400, rng, t0=t)               # hardware drifts
    assert cal.refits >= 1
    assert cal.mean_rel_err(100) < 0.1


# ------------------------------------------------------------- engine
def test_engine_clock_defaults_to_estimate():
    eng = EchoEngine(None, None, ECHO, num_blocks=64)
    assert eng.clock_model is eng.tm
    assert eng.calibrator is None


def test_engine_calibrates_against_perturbed_clock():
    tm = TimeModel.a100()
    clock = TimeModel.a100().perturbed(scale=2.0, jitter=0.02, seed=7)
    eng = EchoEngine(None, None, ECHO_C, num_blocks=256, block_size=16,
                     chunk_size=64, time_model=tm, clock_model=clock,
                     max_running=48)
    online = make_online_requests(list(np.linspace(0.1, 30.0, 40)),
                                  prompt_mean=120, prompt_std=30,
                                  max_new_mean=16, slo=SLO(1.0, 0.1), seed=8)
    offline = make_offline_corpus(6, 48, doc_len=256, question_len=24,
                                  max_new=12, seed=9)
    for r in online + offline:
        eng.submit(r)
    eng.run(max_iters=20_000, until_time=200.0)
    cal = eng.calibrator
    assert cal is not None and cal.refits >= 1
    assert cal.mean_rel_err(100) < 0.15, cal.mean_rel_err(100)
    # the estimate moved off the stock preset toward the 2x truth
    assert eng.tm.gamma != TimeModel.a100().gamma


def test_perfect_clock_run_unchanged_by_calibration_flag():
    """With clock == estimate the calibrated engine must schedule exactly
    like the plain one (no refits fire, predictions already perfect)."""
    def run(policy):
        eng = EchoEngine(None, None, policy, num_blocks=128, block_size=16,
                         chunk_size=32, time_model=TimeModel.a100())
        for r in make_offline_corpus(3, 8, doc_len=96, question_len=16,
                                     max_new=8, seed=11):
            eng.submit(r)
        return eng.run(max_iters=5000)

    a, b = run(ECHO), run(ECHO_C)
    assert [r.t for r in a.iterations] == [r.t for r in b.iterations]


# ------------------------------------------------------------- cluster
def test_heterogeneous_cluster_calibrates_per_replica():
    from repro.cluster import ClusterSimulator
    from repro.core.simulator import clone_requests
    from repro.data import default_tenants, make_multi_tenant_workload

    online, offline = make_multi_tenant_workload(default_tenants(2), 12.0,
                                                 seed=5)
    clocks = [TimeModel.a100().perturbed(scale=2.0, jitter=0.02, seed=3),
              TimeModel.h100()]
    sim = ClusterSimulator(2, ECHO_C, num_blocks=96,
                           time_model=TimeModel.a100(),
                           clock_models=clocks, seed=0)
    sim.submit_all(clone_requests(online) + clone_requests(offline))
    sim.run(until_time=60.0)
    tms = [rep.engine.tm for rep in sim.replicas]
    assert tms[0] is not tms[1]            # per-replica estimate copies
    for rep in sim.replicas:
        cal = rep.engine.calibrator
        assert cal is not None and cal.refits >= 1
        # short run (~200 iters on the slow replica): judge the trailing 50
        assert cal.mean_rel_err(50) < 0.15
    # each replica learned *its own* hardware: the 2x-a100 replica's decode
    # coefficient ends far above the h100 replica's
    assert tms[0].gamma > 2 * tms[1].gamma


def test_fleet_planner_mixed_hardware():
    from repro.cluster import FleetPlanner
    from repro.data import default_tenants, make_multi_tenant_workload

    online, offline = make_multi_tenant_workload(default_tenants(2), 8.0,
                                                 seed=6)
    planner = FleetPlanner(TimeModel.a100(), policy=ECHO_C,
                           clock_models=[TimeModel.a100().perturbed(
                               scale=1.5, seed=2), TimeModel.h100()])
    rep = planner.plan(online, offline, candidate_replicas=(1, 2),
                       candidate_blocks=(96,), duration=20.0)
    assert rep.slo_by_config                 # probed at least one config
    assert rep.min_replicas in (1, 2, None)
