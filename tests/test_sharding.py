"""Divisibility-aware sharding rules (no multi-device runtime needed:
_axes_fit/_leaf_spec only consult mesh.shape)."""
from types import SimpleNamespace

import jax

from repro.launch.sharding import _axes_fit, _leaf_spec

MESH = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def _leaf(shape):
    return SimpleNamespace(shape=shape, ndim=len(shape))


def test_axes_fit_divisibility():
    assert _axes_fit(64, ("model",), MESH) == ("model",)
    assert _axes_fit(40, ("model",), MESH) is None          # llama4 heads
    assert _axes_fit(24, ("model",), MESH) is None          # musicgen heads
    assert _axes_fit(1, ("model",), MESH) is None           # MQA kv
    # batch over (pod, data): largest prefix product dividing the dim
    assert _axes_fit(256, ("pod", "data"), MESH) == ("pod", "data")
    assert _axes_fit(32, ("pod", "data"), MESH) == ("pod", "data")
    assert _axes_fit(16, ("pod", "data"), MESH) == ("pod",)  # 16 % 32 != 0
    assert _axes_fit(1, ("pod", "data"), MESH) is None


def test_param_rules_head_divisibility():
    # qwen3-4b wq (d, 32, 128): heads shard
    spec = _leaf_spec(["layers", "attn", "wq"], _leaf((36, 2560, 32, 128)), MESH)
    assert spec[2] in ("model", ("model",))
    # llama4 wq (d, 40, 128): heads replicate
    spec = _leaf_spec(["wq"], _leaf((5120, 40, 128)), MESH)
    assert spec == jax.sharding.PartitionSpec(None, None, None)
    # granite wk kv=1: replicate
    spec = _leaf_spec(["wk"], _leaf((6144, 1, 128)), MESH)
    assert spec[1] is None


def test_param_rules_experts_and_ffn():
    spec = _leaf_spec(["we1"], _leaf((128, 2048, 768)), MESH)
    assert spec[0] in ("model", ("model",))
    spec = _leaf_spec(["w2"], _leaf((48, 13440, 4096)), MESH)
    assert spec == jax.sharding.PartitionSpec(None, ("model",), None)


def test_zero1_extra_axes():
    # optimizer moments also shard across data: (d, ff) ff = 9728
    spec = _leaf_spec(["w1"], _leaf((2560, 9728)), MESH, extra_axes=("data",))
    assert spec[1] == ("model", "data")                      # 9728 % 256 == 0
    # codeqwen ff=13440: 13440 % 256 != 0 -> model only (graceful)
    spec = _leaf_spec(["w1"], _leaf((4096, 13440)), MESH, extra_axes=("data",))
    assert spec[1] in ("model", ("model",))


def test_unknown_param_replicated():
    spec = _leaf_spec(["A_log"], _leaf((64,)), MESH)
    assert spec == jax.sharding.PartitionSpec(None)
