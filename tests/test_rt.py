"""Real-time serving layer: async lifecycle (stream/abort/drain),
backpressure at both ends, wall-vs-drive equivalence on a paused clock,
EventBus thread safety under a two-thread hammer, engine step-lock
reentrancy, link-calibration fitting, and the TCP front door."""
import asyncio
import threading

import numpy as np
import pytest

from repro.cluster import ClusterSimulator
from repro.core import ECHO, SLO, EchoEngine, TimeModel
from repro.core.request import Request, TaskType
from repro.core.simulator import clone_requests
from repro.data import make_offline_corpus, make_online_requests
from repro.serving import AdmissionConfig, EchoService, HandleStatus
from repro.serving.events import EventBus
from repro.serving.handle import TokenEvent
from repro.rt import (AsyncEchoEngine, EchoServer, ManualClock,
                      RTState, SubmitQueueFull, request_once)
from repro.rt.calibrate import calibrate_link
import repro.rt.calibrate as calibrate_mod

from tests.test_serving import (assert_no_block_leaks,
                                assert_no_owner_pin_leaks)


def _tm():
    return TimeModel.a100()


def _engine(num_blocks=128, **kw):
    return EchoEngine(None, None, ECHO, num_blocks=num_blocks, block_size=16,
                      chunk_size=32, time_model=_tm(), **kw)


def _workload(seed=0, duration=6.0, rate=2.0):
    rng = np.random.default_rng(seed)
    arrivals = list(np.cumsum(rng.exponential(1.0 / rate,
                                              int(rate * duration))))
    online = make_online_requests(arrivals, prompt_mean=48, prompt_std=12,
                                  max_new_mean=8, slo=SLO(1.0, 0.1),
                                  seed=seed + 1)
    offline = make_offline_corpus(3, 8, doc_len=96, question_len=16,
                                  max_new=6, seed=seed + 2)
    return online, offline


def _leakcheck(rt):
    leaks = rt.kv_leaks()
    assert not any(leaks.values()), f"leaked after drain: {leaks}"
    for eng in rt.service.backend.engines():
        assert_no_block_leaks(eng)
        assert_no_owner_pin_leaks(eng)


# ------------------------------------------------------------- lifecycle
def test_stream_and_result():
    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock())
        async with rt:
            h = await rt.submit([1, 2, 3], max_new_tokens=8)
            got = []
            async for ev in h.tokens():
                got.append(ev.token)
                assert ev.index == len(got) - 1
            assert got[0] is not None and len(got) == 8
            res = await h.result()
            assert res.status is HandleStatus.FINISHED
            assert res.tokens == got
            assert h.wall_ttft() is not None
        assert rt.state is RTState.STOPPED
        _leakcheck(rt)
    asyncio.run(main())


def test_graceful_drain_with_inflight_decode():
    """drain() must let requests that are mid-decode finish — not shed
    them — and leave zero KV residue."""
    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock())
        await rt.start()
        hs = [await rt.submit([1 + i, 2, 3], max_new_tokens=24)
              for i in range(6)]
        # wait until at least one token streamed (decode is in flight)
        first = await hs[0].tokens().__anext__()
        assert first.index == 0
        await rt.drain()
        for h in hs:
            res = await h.result()
            assert res.status is HandleStatus.FINISHED, res.status
            assert len(res.tokens) == 24
        assert rt.stats.drain_sheds == 0
        _leakcheck(rt)
        # the front door is closed: late submits are shed, not queued
        late = await rt.submit([9, 9], max_new_tokens=4)
        assert late.status is HandleStatus.SHED
        assert rt.stats.shed_closed == 1
    asyncio.run(main())


def test_drain_flushes_swap_stager():
    """Graceful drain on a host-tiered engine lands every in-flight
    staging transfer (flush hook through the backend)."""
    async def main():
        rt = AsyncEchoEngine(_engine(num_blocks=48, host_kv_blocks=64),
                             clock=ManualClock())
        async with rt:
            online, offline = _workload(seed=3, duration=3.0)
            hs = [await rt.submit_request(r)
                  for r in clone_requests(online + offline)]
            for h in hs:
                await h.result()
        assert rt.service.engine._stager is None or \
            rt.service.engine._stager.inflight_blocks() == 0
        _leakcheck(rt)
    asyncio.run(main())


def test_mid_stream_abort_releases_kv():
    """await handle.abort() mid-decode frees blocks/pins immediately and
    terminates the token stream."""
    async def main():
        rt = AsyncEchoEngine(_engine(num_blocks=64, host_kv_blocks=32),
                             clock=ManualClock())
        async with rt:
            victim = await rt.submit([1] * 40, max_new_tokens=200)
            others = [await rt.submit([7 + i] * 8, max_new_tokens=8)
                      for i in range(3)]
            stream = victim.tokens()
            seen = 0
            async for _ev in stream:
                seen += 1
                if seen == 3:
                    assert await victim.abort() is True
            assert 3 <= seen < 200          # stream ended early
            assert victim.status is HandleStatus.ABORTED
            assert await victim.abort() is False     # already terminal
            res = await victim.result()
            assert res.status is HandleStatus.ABORTED
            for h in others:                # survivors unaffected
                assert (await h.result()).status is HandleStatus.FINISHED
        assert rt.stats.aborted == 1
        _leakcheck(rt)
    asyncio.run(main())


def test_abort_while_still_in_intake_queue():
    """Aborting before the loop ever drains the submit queue must settle
    the handle without touching the backend."""
    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock())
        # not started: the request sits in intake
        h = await rt.submit([1, 2], max_new_tokens=4)
        assert h.status is HandleStatus.QUEUED
        assert await h.abort() is True
        assert h.status is HandleStatus.ABORTED
        await rt.start()
        await rt.drain()
        assert len(rt.service.engine.stats.iterations) == 0
        _leakcheck(rt)
    asyncio.run(main())


# ------------------------------------------------------------- backpressure
def test_submit_queue_sheds_when_saturated():
    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock(),
                             max_submit_queue=4)
        # loop not started: nothing drains the queue, so 4 fit, rest shed
        hs = [await rt.submit([1, i], max_new_tokens=2, wait=False)
              for i in range(10)]
        shed = [h for h in hs if h.status is HandleStatus.SHED]
        assert len(shed) == 6
        assert rt.stats.shed_submit_queue == 6
        for h in shed:                      # shed handles settle instantly
            res = await h.result()
            assert res.status is HandleStatus.SHED
            assert res.tokens == []
        with pytest.raises(SubmitQueueFull):
            rt.try_submit_nowait(Request(prompt=(1,), max_new_tokens=2,
                                         task_type=TaskType.ONLINE,
                                         arrival_time=0.0))
        await rt.start()
        await rt.drain()                    # the 4 queued ones complete
        assert rt.stats.finished == 4
        _leakcheck(rt)
    asyncio.run(main())


def test_slow_consumer_hits_token_queue_cap():
    """A consumer that never reads must be aborted at the queue cap, not
    buffer the whole generation."""
    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock(),
                             token_queue_cap=4)
        async with rt:
            h = await rt.submit([1, 2, 3], max_new_tokens=64)
            res = await h.result()          # never consumes the stream
        assert res.status is HandleStatus.ABORTED
        assert h.overflowed
        assert rt.stats.slow_consumer_aborts == 1
        assert len(res.tokens) < 64
        # the stream still terminates (EOS forced in) for a late reader
        tokens = [ev async for ev in h.tokens()]
        assert len(tokens) <= 4
        _leakcheck(rt)
    asyncio.run(main())


def test_admission_shed_propagates_to_async_handle():
    async def main():
        rt = AsyncEchoEngine(_engine(num_blocks=32),
                             admission=AdmissionConfig(max_online_queue=1),
                             clock=ManualClock())
        async with rt:
            hs = [await rt.submit([1 + i] * 24, max_new_tokens=16)
                  for i in range(30)]
            res = await asyncio.gather(*[h.result() for h in hs])
        statuses = {r.status for r in res}
        assert HandleStatus.SHED in statuses      # queue cap bit
        assert HandleStatus.FINISHED in statuses  # but service kept going
        assert rt.stats.shed == sum(
            r.status is HandleStatus.SHED for r in res)
        _leakcheck(rt)
    asyncio.run(main())


# ------------------------------------------------------------- equivalence
def test_wall_loop_matches_drive_on_paused_clock():
    """The async loop is plumbing, not policy: replaying a trace through
    it (paused serving clock, explicit arrival stamps) must reproduce the
    synchronous drive() path bit-identically."""
    online, offline = _workload(seed=11, duration=5.0, rate=3.0)
    ref_service = EchoService(_engine())
    want = ref_service.drive(clone_requests(online + offline,
                                            preserve_rid=True),
                             max_iters=20_000, until_time=60.0)

    async def main():
        rt = AsyncEchoEngine(_engine(), clock=ManualClock())
        async with rt:
            hs = [await rt.submit_request(r)
                  for r in clone_requests(online + offline,
                                          preserve_rid=True)]
            results = [await h.result() for h in hs]
        _leakcheck(rt)
        return results

    results = asyncio.run(main())
    # engine-domain outcomes must match request by request
    want_by_rid = {r.rid: r for r in want.finished}
    assert len(results) == len(online) + len(offline)
    finished = [r for r in results if r.status is HandleStatus.FINISHED]
    assert len(finished) == len(want.finished)
    for req, res in zip(clone_requests(online + offline, preserve_rid=True),
                        results):
        ref = want_by_rid.get(req.rid)
        if ref is None:
            continue
        assert res.tokens == list(ref.output_tokens), req.rid
        assert res.finish_time == ref.finish_time, req.rid
        assert res.ttft == ref.ttft(), req.rid


def test_wall_loop_matches_drive_on_cluster():
    online, offline = _workload(seed=5, duration=4.0, rate=2.0)

    def sim():
        return ClusterSimulator(2, ECHO, num_blocks=96, time_model=_tm(),
                                seed=0)

    want = EchoService(sim()).drive(
        clone_requests(online + offline, preserve_rid=True),
        until_time=60.0)

    async def main():
        rt = AsyncEchoEngine(sim(), clock=ManualClock())
        async with rt:
            hs = [await rt.submit_request(r)
                  for r in clone_requests(online + offline,
                                          preserve_rid=True)]
            return [await h.result() for h in hs]

    results = asyncio.run(main())
    merged = want.merged()
    finished = [r for r in results if r.status is HandleStatus.FINISHED]
    assert len(finished) == len(merged.finished)
    # same scheduling decisions -> same engine-domain finish times
    want_by_rid = {r.rid: r for r in merged.finished}
    for req, res in zip(clone_requests(online + offline, preserve_rid=True),
                        results):
        if req.rid in want_by_rid:
            assert res.finish_time == want_by_rid[req.rid].finish_time


# ------------------------------------------------------------- wall clock
def test_wall_stamps_use_serving_clock():
    async def main():
        clock = ManualClock()
        rt = AsyncEchoEngine(_engine(), clock=clock)
        h = await rt.submit([1, 2], max_new_tokens=4)
        assert h.t_submit_wall == 0.0
        clock.advance(1.5)
        async with rt:
            res = await h.result()
        assert res.status is HandleStatus.FINISHED
        assert h.t_first_token_wall == 1.5
        assert h.wall_ttft() == 1.5
        assert h.wall_latency() == 1.5
        _leakcheck(rt)
    asyncio.run(main())


# ------------------------------------------------------------- thread safety
def test_event_bus_concurrent_emit_two_thread_hammer():
    """Regression for the off-thread step loop: two threads emitting into
    one bus must never lose a count (the emit path is serialized)."""
    bus = EventBus()
    seen = [0]
    bus.on_finish(lambda h: seen.__setitem__(0, seen[0] + 1))
    N = 5_000

    def hammer():
        for _ in range(N):
            bus.emit("finish", None)

    threads = [threading.Thread(target=hammer) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert seen[0] == 2 * N
    assert bus.dropped_callbacks == 0


def test_live_metrics_concurrent_token_counts_exact():
    from repro.serving.events import LiveMetrics

    class _Req:
        is_online = True

    class _H:
        request = _Req()

    bus = EventBus()
    live = LiveMetrics(bus)
    N = 4_000
    ev = TokenEvent(handle=_H(), token=1, t=0.0, index=1)

    def hammer():
        for _ in range(N):
            bus.emit("token", ev)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert live.online_tokens == 4 * N


def test_engine_step_rejects_reentry():
    """The step lock must fail loudly on a second concurrent driver rather
    than corrupt scheduler/KV state."""
    eng = _engine()
    eng.submit(Request(prompt=(1, 2, 3), max_new_tokens=4,
                       task_type=TaskType.ONLINE, arrival_time=0.0))
    entered = threading.Event()
    release = threading.Event()
    errors = []

    orig = eng._step_impl

    def slow_step():
        entered.set()
        release.wait(5.0)
        return orig()

    eng._step_impl = slow_step
    t = threading.Thread(target=eng.step)
    t.start()
    assert entered.wait(5.0)
    with pytest.raises(RuntimeError, match="re-entered"):
        eng.step()
    release.set()
    t.join(5.0)
    eng._step_impl = orig
    eng.run(100)                            # engine still healthy


# ------------------------------------------------------------- calibration
def test_fit_swap_recovers_synthetic_link():
    tm = TimeModel.a100()
    byte_s, floor = 2e-10, 5e-5             # 5 GB/s + 50us floor
    samples = [(n, byte_s * n + floor)
               for n in (1 << 16, 1 << 18, 1 << 20, 1 << 22)]
    tm.fit_swap(samples)
    assert tm.swap_byte == pytest.approx(byte_s, rel=1e-6)
    assert tm.swap_floor == pytest.approx(floor, rel=1e-6)


def test_calibrate_link_without_jax_keeps_presets(monkeypatch):
    tm = TimeModel.a100()
    before = (tm.swap_byte, tm.swap_floor, tm.swap_launch)
    monkeypatch.setattr(calibrate_mod, "_import_jax",
                        lambda: (None, None))
    cal = calibrate_link(tm)
    assert not cal.applied
    assert cal.error == "jax not importable"
    assert (tm.swap_byte, tm.swap_floor, tm.swap_launch) == before


def test_calibrate_link_degenerate_fit_restores_presets(monkeypatch):
    tm = TimeModel.a100()
    before = (tm.swap_byte, tm.swap_floor, tm.swap_launch)
    # all-equal timings -> zero fitted byte rate -> keep presets
    monkeypatch.setattr(calibrate_mod, "measure_link",
                        lambda sizes, repeats: [(1 << 18, 1e-4),
                                                (1 << 22, 1e-4)])
    cal = calibrate_link(tm, overlap=False)
    assert not cal.applied and "degenerate" in cal.error
    assert (tm.swap_byte, tm.swap_floor, tm.swap_launch) == before


def test_calibrate_link_real_backend_smoke():
    """With jax present the calibration must either apply a positive byte
    rate or explain why it kept the presets — and never raise."""
    tm = TimeModel.a100()
    cal = calibrate_link(tm, sizes=(1 << 16, 1 << 18), repeats=1)
    if cal.applied:
        assert tm.swap_byte > 0.0
        assert cal.bandwidth_gbs > 0.0
    else:
        assert cal.error


# ------------------------------------------------------------- TCP server
def test_tcp_server_roundtrip_and_drain():
    async def main():
        rt = AsyncEchoEngine(_engine())
        await rt.start()
        srv = await EchoServer(rt, port=0).start()
        host, port = srv.address
        outs = await asyncio.gather(*[
            request_once(host, port, [1, 2, 3 + i], max_new_tokens=4)
            for i in range(8)])
        assert all(o["status"] == "finished" for o in outs)
        assert all(len(o["tokens"]) == 4 for o in outs)
        await srv.close()
        assert srv.requests_served == 8
        _leakcheck(rt)
    asyncio.run(main())


def test_tcp_server_disconnect_aborts_inflight():
    async def main():
        rt = AsyncEchoEngine(_engine())
        await rt.start()
        srv = await EchoServer(rt, port=0).start()
        host, port = srv.address
        reader, writer = await asyncio.open_connection(host, port)
        import json as _json
        writer.write(_json.dumps({"prompt": [1] * 30,
                                  "max_new_tokens": 500}).encode() + b"\n")
        await writer.drain()
        await reader.readline()             # one token arrived
        writer.close()                      # hang up mid-stream
        try:
            await writer.wait_closed()
        except ConnectionResetError:
            pass
        # the server aborts the orphaned request; drain must not hang
        await asyncio.wait_for(srv.close(), timeout=30.0)
        assert rt.stats.aborted >= 1
        _leakcheck(rt)
    asyncio.run(main())


def test_tcp_server_rejects_malformed_request():
    async def main():
        rt = AsyncEchoEngine(_engine())
        await rt.start()
        srv = await EchoServer(rt, port=0).start()
        host, port = srv.address
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(b'{"nope": 1}\n')
        await writer.drain()
        import json as _json
        err = _json.loads(await reader.readline())
        assert "error" in err
        # connection survives: a valid request still works
        writer.write(_json.dumps({"prompt": [1, 2],
                                  "max_new_tokens": 2}).encode() + b"\n")
        await writer.drain()
        lines = [await reader.readline() for _ in range(3)]
        assert _json.loads(lines[-1])["done"]
        writer.close()
        await srv.close()
        _leakcheck(rt)
    asyncio.run(main())


# ------------------------------------------------------------- observability
def test_rt_probe_wall_histograms_and_spans():
    from repro.obs import MetricsRegistry, Tracer
    from repro.obs.trace import RT_PID

    async def main():
        clock = ManualClock()
        rt = AsyncEchoEngine(_engine(), clock=clock)
        tracer = Tracer()
        reg = rt.instrument(MetricsRegistry(), tracer)
        async with rt:
            hs = [await rt.submit([1, 2, 3 + i], max_new_tokens=4)
                  for i in range(3)]
            for h in hs:
                await h.result()
        assert reg.get("rt_requests_total").labels("finished").value == 3
        assert reg.get("rt_ttft_wall_seconds").percentile(0.5) is not None
        rt_events = [e for e in tracer._events if e[4] == RT_PID]
        assert len(rt_events) >= 3          # one span per connection
        _leakcheck(rt)
    asyncio.run(main())
