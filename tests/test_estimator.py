"""Estimator toolkits: Eq.6-8 fit recovery, memory + rate predictors."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.estimator import MemoryPredictor, RatePredictor, TimeModel


def test_prefill_fit_recovers_coefficients():
    true = TimeModel(alpha=3e-8, beta=2e-6, c=1e-4)
    ls = [64, 128, 256, 512, 1024, 2048, 4096]
    samples = [(l, true.prefill_time([(0, l)])) for l in ls]
    tm = TimeModel()
    tm.fit_prefill(samples)
    for l in (100, 1000, 3000):
        want = true.prefill_time([(0, l)])
        got = tm.prefill_time([(0, l)])
        assert abs(want - got) / want < 0.1, (l, want, got)


def test_decode_fit_recovers():
    true = TimeModel(gamma=2e-7, delta=5e-7, d0=1e-6)
    samples = []
    rng = np.random.default_rng(0)
    for _ in range(20):
        lens = rng.integers(10, 2000, rng.integers(1, 16))
        samples.append((int(lens.max()), float(lens.mean()),
                        true.decode_time(lens)))
    tm = TimeModel()
    tm.fit_decode(samples)
    assert abs(tm.gamma - true.gamma) / true.gamma < 0.15
    assert abs(tm.delta - true.delta) / true.delta < 0.15


def test_lambda_fit():
    true = TimeModel(lam=0.7)
    samples = []
    rng = np.random.default_rng(1)
    for _ in range(30):
        tp, td = rng.uniform(0.01, 0.1, 2)
        samples.append((tp, td, true.lam * max(tp, td) + (1 - true.lam) * min(tp, td)))
    tm = TimeModel()
    tm.fit_lambda(samples)
    assert abs(tm.lam - 0.7) < 0.05


def test_chunked_prefill_spans_consistent():
    """Chunked spans sum to the full-prefill quadratic cost (minus floors)."""
    tm = TimeModel(alpha=1e-7, beta=1e-5, c=0.0)
    full = tm.prefill_time([(0, 1024)])
    chunks = tm.prefill_time([(0, 256), (256, 512), (512, 768), (768, 1024)])
    assert abs(full - chunks) < 1e-9


def test_memory_predictor_mu_sigma():
    mp = MemoryPredictor(window=100.0, k_sigma=2.0)
    rng = np.random.default_rng(2)
    vals = rng.normal(1000, 100, 200)
    for i, v in enumerate(vals):
        mp.observe(i * 0.5, v)
    pred = mp.predict()
    assert 1100 < pred < 1350                 # mu + 2 sigma
    thr = mp.threshold_blocks(total_blocks=256, block_size=16)
    assert 256 - int(np.ceil(pred / 16)) == thr or thr == int(256 * 0.3)


def test_rate_predictor_tracks_rate():
    rp = RatePredictor(window=60.0)
    t = 0.0
    rng = np.random.default_rng(3)
    while t < 120:
        t += rng.exponential(1 / 5.0)         # 5 arrivals / s
        rp.observe(t)
    pred = rp.predict_rate(120.0)
    assert 4.0 < pred < 8.0                   # >= mean, includes +sigma


def test_rate_predictor_not_diluted_during_warmup():
    """Regression: with observed history much shorter than the window, the
    predictor must bin only over elapsed time — previously the empty
    cold-start bins diluted the rate ~window/elapsed-fold."""
    rp = RatePredictor(window=900.0)
    t = 0.0
    rng = np.random.default_rng(4)
    while t < 120:                            # 120s of 5/s arrivals
        t += rng.exponential(1 / 5.0)
        rp.observe(t)
    pred = rp.predict_rate(120.0)
    assert pred >= 4.0, pred                  # was ~0.7 with full-window bins


def test_rate_predictor_single_bin_warmup():
    """Under one bin of history: single-bin mean, no absurd explosion."""
    rp = RatePredictor(window=900.0)
    for t in (0.0, 10.0, 20.0, 30.0):
        rp.observe(t)
    pred = rp.predict_rate(30.0, bin_s=60.0)
    assert 0.1 < pred < 1.0                   # ~4 arrivals / 30s


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 4096), min_size=1, max_size=8),
       st.lists(st.integers(1, 4096), min_size=0, max_size=8))
def test_batch_time_bounds(prefill_lens, decode_lens):
    """Eq.8: max(Tp,Td) <= T_batch <= Tp+Td when lam in [0,1]."""
    tm = TimeModel(alpha=1e-8, beta=1e-6, c=1e-5, gamma=1e-7, delta=1e-7,
                   d0=1e-5, lam=0.8)
    spans = [(0, l) for l in prefill_lens]
    tp = tm.prefill_time(spans)
    td = tm.decode_time(decode_lens) if decode_lens else 0.0
    t = tm.batch_time(spans, decode_lens)
    if td == 0.0:
        assert abs(t - tp) < 1e-12
    else:
        # Eq.8 with lam in [0, 1.5]: overlap can dip below max but the
        # batch never costs less than either floor's min, nor more than sum
        assert min(tp, td) - 1e-12 <= t <= tp + td + 1e-12
