"""Elastic fleet: replica lifecycle, chaos kill/straggler injection,
re-dispatch accounting, autoscaling, and cross-replica KV migration."""
import jax
import pytest

from repro.cluster import (ChaosConfig, ClusterSimulator, FleetController,
                           FleetPlanner, Replica, ReplicaState,
                           first_block_hash)
from repro.core import ECHO, SLO, Request, TaskType, TimeModel
from repro.core.estimator import DegradedClock
from repro.core.simulator import clone_requests
from repro.data import TenantSpec, make_multi_tenant_workload


def _tm():
    return TimeModel.a100()


def _online(plen=64, t=0.0, max_new=8):
    return Request(prompt=tuple(range(plen)), max_new_tokens=max_new,
                   task_type=TaskType.ONLINE, arrival_time=t,
                   slo=SLO(1.0, 0.1))


def _offline(prompt, t=0.0, max_new=4):
    return Request(prompt=tuple(prompt), max_new_tokens=max_new,
                   task_type=TaskType.OFFLINE, arrival_time=t)


def _workload(duration=8.0, seed=0, n_docs=4, questions=12):
    tenants = (TenantSpec("a", online_rate=2.0, n_docs=n_docs,
                          questions_per_doc=questions),
               TenantSpec("b", online_rate=1.0, slo=SLO(1.5, 0.15),
                          n_docs=n_docs, questions_per_doc=questions))
    return make_multi_tenant_workload(tenants, duration, seed=seed)


def _sim(n=2, **kw):
    kw.setdefault("num_blocks", 96)
    kw.setdefault("time_model", _tm())
    return ClusterSimulator(n, ECHO, seed=0, **kw)


# ---------------------------------------------------------------- lifecycle
def test_replica_lifecycle_transitions():
    rep = Replica.simulated(0, ECHO, num_blocks=32, time_model=_tm(),
                            state=ReplicaState.JOINING)
    assert not rep.routable and rep.t_up is None
    rep.mark_up(3.0)
    assert rep.state == ReplicaState.UP and rep.routable and rep.t_up == 3.0
    assert rep.engine.now >= 3.0, "a joiner's clock cannot lag the fleet"

    rep.degrade(3.0)
    assert rep.state == ReplicaState.DEGRADED
    assert rep.routable, "a straggler still takes work"
    assert isinstance(rep.engine.clock_model, DegradedClock)
    assert rep.engine.clock_model.slowdown == 3.0
    # the scheduler's estimate is untouched — a straggler plans as healthy
    assert not isinstance(rep.engine.tm, DegradedClock)
    rep.degrade(5.0)           # re-degrade replaces, never nests
    assert rep.engine.clock_model.slowdown == 5.0
    assert not isinstance(rep.engine.clock_model.base, DegradedClock)
    rep.restore()
    assert rep.state == ReplicaState.UP
    assert not isinstance(rep.engine.clock_model, DegradedClock)

    rep.begin_drain()
    assert rep.state == ReplicaState.DRAINING and not rep.routable
    rep.mark_down(10.0)
    assert rep.state == ReplicaState.DOWN
    assert rep.replica_seconds(99.0) == pytest.approx(7.0)


def test_add_replica_joins_after_delay():
    sim = _sim(1, join_delay=1.0)
    online, offline = _workload(duration=4.0)
    sim.submit_all(clone_requests(online) + clone_requests(offline))
    sim.run(until_time=2.0)
    rep = sim.add_replica()
    t_add = sim.now
    assert rep.state == ReplicaState.JOINING
    assert rep not in sim.router.routable()
    stats = sim.run(until_time=100.0)
    assert rep.state == ReplicaState.UP
    assert rep.t_up == pytest.approx(t_add + 1.0)
    states = [(rid, s) for _, rid, s in stats.lifecycle if rid == rep.id]
    assert states == [(rep.id, "joining"), (rep.id, "up")]
    on, off = stats.finished_counts()
    assert on == len(online) and off == len(offline)


def test_drain_refuses_last_routable_replica():
    sim = _sim(2)
    assert sim.drain_replica(0)
    assert not sim.drain_replica(1), "never drain the last home of work"
    assert sim.replicas[1].state == ReplicaState.UP


# ------------------------------------------------------------- chaos: kill
def test_kill_redispatches_with_zero_leaks():
    online, offline = _workload()
    sim = _sim(2)
    sim.submit_all(clone_requests(online) + clone_requests(offline))
    sim.run(until_time=1.5)
    victim = max(sim.replicas,
                 key=lambda r: len(r.inflight_requests()))
    assert victim.inflight_requests(), "kill must strand in-flight work"

    rec = sim.kill_replica(victim.id)
    assert rec is not None and rec.rids
    assert rec.redispatched_online + rec.redispatched_offline \
        == len(rec.rids)
    assert rec.lost_tokens > 0, "computed KV must be discarded at the kill"
    # the dead replica holds nothing: no device refs, no pins, no queues
    eng = victim.engine
    assert sum(b.ref for b in eng.bm.blocks) == 0
    assert all(b.unfinished_owners == 0 for b in eng.bm.blocks)
    assert len(eng.pool) == 0 and not eng.pending
    assert not victim.has_work()
    assert victim.state == ReplicaState.DOWN

    stats = sim.run(until_time=200.0)
    fin = {r.rid for r in stats.merged().finished}
    assert set(rec.rids) <= fin, "every evacuee must finish on a survivor"
    on, off = stats.finished_counts()
    assert on == len(online) and off == len(offline)
    lats = stats.recovery_latencies()
    assert len(lats) == len(rec.rids)
    assert stats.lost_tokens == rec.lost_tokens


def test_kill_last_replica_requeues_until_joiner_arrives():
    sim = _sim(1)
    online, offline = _workload(duration=3.0)
    sim.submit_all(clone_requests(online) + clone_requests(offline))
    sim.run(until_time=1.0)
    rec = sim.kill_replica(0)
    assert rec.rids, "the kill must strand work"
    assert not sim.router.routable()
    pending_rids = {r.rid for _, _, r in sim._pending}
    assert set(rec.rids) <= pending_rids, \
        "with no survivor the evacuees re-enter the arrival heap"
    rep = sim.add_replica()
    stats = sim.run(until_time=200.0)
    assert rep.state == ReplicaState.UP
    on, off = stats.finished_counts()
    assert on == len(online) and off == len(offline)


def test_chaos_sample_is_seed_deterministic():
    a = ChaosConfig.sample(4, 30.0, seed=3, kill_prob=0.5, degrade_prob=0.3)
    b = ChaosConfig.sample(4, 30.0, seed=3, kill_prob=0.5, degrade_prob=0.3)
    c = ChaosConfig.sample(4, 30.0, seed=4, kill_prob=0.5, degrade_prob=0.3)
    assert (a.kills, a.degrades) == (b.kills, b.degrades)
    assert (a.kills, a.degrades) != (c.kills, c.degrades)


# -------------------------------------------------------- chaos: straggler
def test_straggler_slows_ground_truth_and_restores():
    online, offline = _workload(duration=6.0)
    chaos = ChaosConfig(degrades=[(0.5, 0, 4.0, 4.0)])
    healthy, degraded = _sim(1), _sim(1, chaos=chaos)
    for sim in (healthy, degraded):
        sim.submit_all(clone_requests(online, preserve_rid=True)
                       + clone_requests(offline, preserve_rid=True))
    h = healthy.run(until_time=200.0)
    d = degraded.run(until_time=200.0)
    on, off = d.finished_counts()
    assert on == len(online) and off == len(offline)
    assert degraded.fleet_now() > healthy.fleet_now(), \
        "a 4x straggler episode must show up as a longer makespan"
    assert [s for _, _, s in d.lifecycle] == ["degraded", "up"]
    # clock unwrapped after the episode
    assert not isinstance(degraded.replicas[0].engine.clock_model,
                          DegradedClock)
    assert min(d.slo_attainment("ttft"), d.slo_attainment("tpot")) \
        <= min(h.slo_attainment("ttft"), h.slo_attainment("tpot")) + 1e-9


# -------------------------------------------------------------- autoscaler
def test_autoscaler_adds_on_burst_then_drains_idle():
    ctrl = FleetController(min_replicas=1, max_replicas=3,
                           rate_per_replica=3.0, interval=0.5,
                           cooldown=1.0, queue_high=2, window=4.0,
                           bin_s=1.0)
    sim = _sim(1, autoscaler=ctrl, join_delay=0.25)
    reqs = [_online(96, t=i * 0.05, max_new=16) for i in range(60)]
    reqs += [_online(64, t=4.0 + i * 1.0, max_new=4) for i in range(16)]
    sim.submit_all(clone_requests(reqs))
    stats = sim.run(until_time=200.0)
    assert ctrl.n_added > 0, "the burst must trigger a scale-up"
    assert ctrl.n_drained > 0, "the quiet tail must trigger a scale-down"
    assert len(sim.replicas) <= 1 + ctrl.n_added
    assert len(sim.router.routable()) >= ctrl.min_replicas
    on, _ = stats.finished_counts()
    assert on == len(reqs)
    # drained replicas were idle when cut loose: nothing may be lost
    assert stats.replica_seconds < len(sim.replicas) * sim.fleet_now()


def test_autoscaler_never_exceeds_max_replicas():
    ctrl = FleetController(min_replicas=1, max_replicas=2,
                           rate_per_replica=0.5, interval=0.5,
                           cooldown=0.5, queue_high=1)
    sim = _sim(1, autoscaler=ctrl, join_delay=0.25)
    sim.submit_all([_online(128, t=i * 0.02, max_new=16) for i in range(80)])
    sim.run(until_time=200.0)
    assert len(sim.replicas) <= 2


def test_autoscaler_calibrates_rate_from_planner():
    online, _ = _workload(duration=6.0)
    ctrl = FleetController(min_replicas=1, max_replicas=3)
    rate = ctrl.calibrate(FleetPlanner(_tm(), seed=0),
                          [r for r in online if r.is_online],
                          num_blocks=96, duration=12.0)
    assert rate is not None and rate > 0
    assert ctrl.rate_per_replica == rate
    assert ctrl.desired_replicas(0.0) >= 1


# -------------------------------------------------- migration: virtual clock
def test_drain_migrates_parked_prefix_and_charges_fabric():
    sim = _sim(2, host_kv_blocks=128)
    bs = sim.replicas[0].engine.bm.block_size
    doc = tuple(range(5000, 5000 + 8 * bs))
    # establish the group's home: run a few members to completion so the
    # document prefix sits cached (unreferenced) on one replica
    seeds = [_offline(doc + (i,), t=0.0, max_new=4) for i in range(3)]
    sim.submit_all(clone_requests(seeds))
    sim.run(until_time=100.0)
    home = max(sim.replicas,
               key=lambda r: r.affinity(first_block_hash(seeds[0], bs)))
    # queue fresh group members on the home, then drain it: the evacuees
    # re-dispatch to the survivor and the parked prefix ships with them
    late = [_offline(doc + (100 + i,), t=sim.now, max_new=4)
            for i in range(6)]
    for r in clone_requests(late):
        home.submit(r)
    assert sim.drain_replica(home.id)
    other = next(r for r in sim.replicas if r is not home)
    assert sim.router.stats.migrations > 0
    assert sim.router.stats.migrated_bytes > 0
    assert other.engine.bm.metrics.migrated_in_blocks > 0
    stats = sim.run(until_time=300.0)
    on, off = stats.finished_counts()
    assert off == len(seeds) + len(late)
    assert home.state == ReplicaState.DOWN
    # the migrated prefix was restored, not recomputed: the new home
    # swapped those blocks in from its host tier
    assert other.engine.bm.metrics.swapped_in_tokens > 0
    assert other.engine.stats.migrated_in_bytes > 0, \
        "fabric time must be charged on the destination's clock"


def test_migrate_time_terms_priced():
    tm = _tm()
    assert tm.migrate_time(0) == 0.0
    one_mb = tm.migrate_time(1 << 20)
    assert one_mb > tm.migrate_floor > 0
    assert tm.migrate_time(2 << 20) > one_mb
    assert tm.migrate_time(1 << 20) > tm.swap_time(1 << 20), \
        "the inter-node fabric is slower than the local PCIe hop"


# ------------------------------------------------ migration: real runner
def test_migrated_prefix_is_bit_exact_with_paged_runner(tiny_model):
    """Acceptance: a migrated prefix must restore into the destination
    engine's attention exactly as locally computed KV would — same greedy
    tokens from the re-homed question as from the original home."""
    from test_engine import _reference_generate

    from repro.core.engine import EchoEngine

    model, params = tiny_model

    def make_engine():
        return EchoEngine(model, params, ECHO, num_blocks=16, block_size=8,
                          chunk_size=16, max_pages_per_seq=16,
                          host_kv_blocks=32)

    import numpy as np
    rng = np.random.default_rng(5)
    vocab = model.cfg.vocab_size
    doc = tuple(int(x) for x in rng.integers(0, vocab, 48))    # 6 blocks
    q = tuple(int(x) for x in rng.integers(0, vocab, 8))

    src = make_engine()
    seed_req = _offline(doc, max_new=2)
    src.submit(seed_req)
    src.run(max_iters=200)
    assert seed_req.done

    local = _offline(doc + q, max_new=6)
    src.submit(local)
    src.run(max_iters=200)
    assert local.done

    hbs, n_bytes = src.export_prefix(doc)
    assert hbs and n_bytes > 0
    payloads = [hb.payload for hb in hbs]
    assert all(p is not None for p in payloads), \
        "a real-runner export must carry the actual KV pages"

    dst = make_engine()
    admitted = dst.import_prefix(hbs)
    assert admitted == n_bytes
    moved = _offline(doc + q, max_new=6)
    dst.submit(moved)
    dst.run(max_iters=200)
    assert moved.done
    assert dst.bm.metrics.migrated_in_blocks == len(hbs)
    assert dst.bm.metrics.swapped_in_tokens > 0, \
        "the question must restore the migrated prefix, not recompute it"
    ref = _reference_generate(model, params, doc + q, 6)
    assert moved.output_tokens == ref, "migrated KV diverged from computed"
    assert local.output_tokens == ref


def test_export_import_roundtrip_dedups(tiny_model):
    model, params = tiny_model
    from repro.core.engine import EchoEngine
    src = EchoEngine(model, params, ECHO, num_blocks=16, block_size=8,
                     chunk_size=16, max_pages_per_seq=16, host_kv_blocks=32)
    import numpy as np
    rng = np.random.default_rng(9)
    doc = tuple(int(x) for x in
                rng.integers(0, model.cfg.vocab_size, 24))     # 3 blocks
    r = _offline(doc, max_new=2)
    src.submit(r)
    src.run(max_iters=100)
    hbs, _ = src.export_prefix(doc)
    assert hbs
    dst = EchoEngine(None, None, ECHO, num_blocks=16, block_size=8,
                     chunk_size=16, host_kv_blocks=32)
    first = dst.import_prefix(hbs)
    again = dst.import_prefix(hbs)
    assert first > 0
    assert again == 0, "duplicate imports must not cross the fabric twice"
    assert dst.bm.metrics.migrated_in_blocks == len(hbs)


# ------------------------------------------------------------ determinism
def test_chaos_run_is_deterministic():
    online, offline = _workload(duration=5.0)
    chaos = ChaosConfig(kills=[(1.0, 0)], degrades=[(0.5, 1, 3.0, 2.0)])

    def run():
        sim = _sim(2, chaos=chaos)
        sim.submit_all(clone_requests(online, preserve_rid=True)
                       + clone_requests(offline, preserve_rid=True))
        stats = sim.run(until_time=200.0)
        return (sorted((r.rid, tuple(r.output_tokens))
                       for r in stats.merged().finished),
                stats.lifecycle)

    assert run() == run()


pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

_ = jax  # tiny_model fixture pulls in jax; keep the import explicit
