"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,bs,nblk", [
    (2, 4, 2, 32, 8, 4),
    (3, 8, 1, 64, 16, 3),     # MQA
    (1, 6, 6, 16, 8, 2),      # MHA
])
def test_paged_attention_sweep(dtype, b, hq, hkv, hd, bs, nblk):
    rng = jax.random.PRNGKey(b * 31 + hq)
    ks = jax.random.split(rng, 4)
    p = nblk * b + 2
    q = jax.random.normal(ks[0], (b, hq, hd), dtype)
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd), dtype)
    bt = jax.random.randint(ks[3], (b, nblk), 0, p)
    cl = jnp.asarray(np.random.default_rng(0).integers(1, nblk * bs, b), jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, bt, cl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sc,t,hq,hkv,hd,ctx", [
    (64, 128, 4, 2, 32, 0),
    (64, 128, 4, 2, 32, 37),
    (32, 64, 2, 1, 64, 30),
])
def test_chunked_prefill_sweep(dtype, sc, t, hq, hkv, hd, ctx):
    rng = jax.random.PRNGKey(sc + ctx)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (sc, hq, hd), dtype)
    k = jax.random.normal(ks[1], (t, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (t, hkv, hd), dtype)
    out = chunked_prefill_attention(q, k, v, ctx, blk_q=32, blk_k=32,
                                    interpret=True)
    want = ref.ref_chunked_prefill_attention(q, k, v, ctx)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 2, 8, 4, 16),
    (1, 128, 4, 16, 8, 32),
    (3, 32, 1, 4, 16, 16),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    rng = jax.random.PRNGKey(s + h)
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dta = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    y, fs = ssd_scan(x, dta, bm, cm, chunk=chunk, interpret=True)
    y_ref, fs_ref = ref.ref_ssd_sequential(x, dta, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_ignores_garbage_pages():
    """Pages not referenced by the block table must not affect output."""
    rng = jax.random.PRNGKey(9)
    ks = jax.random.split(rng, 4)
    b, hq, hkv, hd, bs, nblk, p = 1, 2, 1, 16, 8, 2, 6
    q = jax.random.normal(ks[0], (b, hq, hd))
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd))
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd))
    bt = jnp.array([[1, 3]], jnp.int32)
    cl = jnp.array([12], jnp.int32)
    out1 = paged_attention(q, kp, vp, bt, cl, interpret=True)
    kp2 = kp.at[0].set(999.0).at[2].set(-999.0)
    vp2 = vp.at[4].set(123.0)
    out2 = paged_attention(q, kp2, vp2, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


@pytest.mark.parametrize("b,s,w,chunk,blk_w", [
    (2, 64, 32, 16, 32),
    (1, 128, 64, 32, 32),
    (3, 32, 16, 16, 16),
])
def test_rglru_scan_sweep(b, s, w, chunk, blk_w):
    from repro.kernels.rglru_scan import rglru_scan
    rng = jax.random.PRNGKey(s + w)
    ks = jax.random.split(rng, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w)))
    bb = jax.random.normal(ks[1], (b, s, w))
    got = rglru_scan(a, bb, chunk=chunk, blk_w=blk_w, interpret=True)
    want = ref.ref_rglru_scan(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
