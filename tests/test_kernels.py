"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention, paged_attention_splitk
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,bs,nblk", [
    (2, 4, 2, 32, 8, 4),
    (3, 8, 1, 64, 16, 3),     # MQA
    (1, 6, 6, 16, 8, 2),      # MHA
])
def test_paged_attention_sweep(dtype, b, hq, hkv, hd, bs, nblk):
    rng = jax.random.PRNGKey(b * 31 + hq)
    ks = jax.random.split(rng, 4)
    p = nblk * b + 2
    q = jax.random.normal(ks[0], (b, hq, hd), dtype)
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd), dtype)
    bt = jax.random.randint(ks[3], (b, nblk), 0, p)
    cl = jnp.asarray(np.random.default_rng(0).integers(1, nblk * bs, b), jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, bt, cl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sc,t,hq,hkv,hd,ctx", [
    (64, 128, 4, 2, 32, 0),
    (64, 128, 4, 2, 32, 37),
    (32, 64, 2, 1, 64, 30),
])
def test_chunked_prefill_sweep(dtype, sc, t, hq, hkv, hd, ctx):
    rng = jax.random.PRNGKey(sc + ctx)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (sc, hq, hd), dtype)
    k = jax.random.normal(ks[1], (t, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (t, hkv, hd), dtype)
    out = chunked_prefill_attention(q, k, v, ctx, blk_q=32, blk_k=32,
                                    interpret=True)
    want = ref.ref_chunked_prefill_attention(q, k, v, ctx)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 2, 8, 4, 16),
    (1, 128, 4, 16, 8, 32),
    (3, 32, 1, 4, 16, 16),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    rng = jax.random.PRNGKey(s + h)
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dta = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    y, fs = ssd_scan(x, dta, bm, cm, chunk=chunk, interpret=True)
    y_ref, fs_ref = ref.ref_ssd_sequential(x, dta, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_ignores_garbage_pages():
    """Pages not referenced by the block table must not affect output."""
    rng = jax.random.PRNGKey(9)
    ks = jax.random.split(rng, 4)
    b, hq, hkv, hd, bs, nblk, p = 1, 2, 1, 16, 8, 2, 6
    q = jax.random.normal(ks[0], (b, hq, hd))
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd))
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd))
    bt = jnp.array([[1, 3]], jnp.int32)
    cl = jnp.array([12], jnp.int32)
    out1 = paged_attention(q, kp, vp, bt, cl, interpret=True)
    kp2 = kp.at[0].set(999.0).at[2].set(-999.0)
    vp2 = vp.at[4].set(123.0)
    out2 = paged_attention(q, kp2, vp2, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def _paged_case(seed, b, hq, hkv, hd, bs, nblk, ctx_lens, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = nblk * b + 2
    q = jax.random.normal(ks[0], (b, hq, hd), dtype)
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd), dtype)
    bt = jax.random.randint(ks[3], (b, nblk), 0, p)
    cl = jnp.asarray(ctx_lens, jnp.int32)
    return q, kp, vp, bt, cl


PAGED_DECODE_CASES = [
    # (b, hq, hkv, hd, bs, nblk, ctx_lens) — GQA group sizes 1 / 4 / 8,
    # ragged batches, and contexts shorter than a single page
    (2, 4, 4, 32, 8, 4, [32, 17]),            # g=1 (MHA)
    (3, 8, 2, 64, 16, 6, [96, 5, 48]),        # g=4, ragged + ctx < page
    (2, 8, 1, 32, 8, 5, [40, 3]),             # g=8 (MQA), ctx < page
    (4, 4, 1, 16, 4, 3, [12, 1, 7, 9]),       # g=4, every ctx ragged
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PAGED_DECODE_CASES)
@pytest.mark.parametrize("pages_per_split", [1, 2, 4])
def test_paged_attention_splitk_sweep(dtype, case, pages_per_split):
    b, hq, hkv, hd, bs, nblk, ctx_lens = case
    q, kp, vp, bt, cl = _paged_case(b * 7 + hq, b, hq, hkv, hd, bs, nblk,
                                    ctx_lens, dtype)
    out = paged_attention_splitk(q, kp, vp, bt, cl,
                                 pages_per_split=pages_per_split,
                                 interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, bt, cl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PAGED_DECODE_CASES)
def test_paged_attention_legacy_sweep(dtype, case):
    """Same sweep through the legacy single-pass kernel: both code paths
    must agree with the oracle on identical inputs."""
    b, hq, hkv, hd, bs, nblk, ctx_lens = case
    q, kp, vp, bt, cl = _paged_case(b * 7 + hq, b, hq, hkv, hd, bs, nblk,
                                    ctx_lens, dtype)
    out = paged_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, bt, cl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_paged_attention_splitk_oversized_split():
    """pages_per_split larger than the whole table degenerates to a single
    split and must still match."""
    q, kp, vp, bt, cl = _paged_case(3, 2, 4, 2, 32, 8, 4, [32, 9], jnp.float32)
    out = paged_attention_splitk(q, kp, vp, bt, cl, pages_per_split=64,
                                 interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sc,t,hq,hkv,hd,ctx,blk_q,blk_k", [
    (100, 420, 4, 1, 32, 250, 32, 64),   # nothing divides anything
    (65, 131, 8, 2, 32, 66, 32, 32),     # off-by-one past block edges
    (7, 16, 4, 4, 16, 9, 32, 32),        # chunk smaller than one block
    (64, 192, 8, 8, 32, 128, 16, 48),    # g=1, blk_k not a divisor of t
])
def test_chunked_prefill_nondivisible_sweep(dtype, sc, t, hq, hkv, hd, ctx,
                                            blk_q, blk_k):
    rng = jax.random.PRNGKey(sc * 3 + ctx)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (sc, hq, hd), dtype)
    k = jax.random.normal(ks[1], (t, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (t, hkv, hd), dtype)
    out = chunked_prefill_attention(q, k, v, ctx, blk_q=blk_q, blk_k=blk_k,
                                    interpret=True)
    assert out.shape == q.shape and out.dtype == q.dtype
    want = ref.ref_chunked_prefill_attention(q, k, v, ctx)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_ops_dispatch_and_tuning():
    """ops-layer routing: impl="ref" is the oracle, impl="splitk"/"pallas"
    agree with it, presets resolve to per-backend tuning tables."""
    q, kp, vp, bt, cl = _paged_case(11, 2, 8, 2, 32, 8, 4, [32, 11],
                                    jnp.float32)
    want = ops.paged_attention(q, kp, vp, bt, cl, impl="ref")
    np.testing.assert_allclose(
        np.asarray(ref.ref_paged_attention(q, kp, vp, bt, cl)),
        np.asarray(want), rtol=0, atol=0)
    for impl in ("splitk", "pallas"):
        got = ops.paged_attention(q, kp, vp, bt, cl, impl=impl, preset="cpu")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    assert ops.kernel_tuning("h100").pages_per_split > \
        ops.kernel_tuning("cpu").pages_per_split
    assert ops.kernel_tuning(None) == ops.kernel_tuning("cpu")  # CPU backend
    with pytest.raises(ValueError):
        ops.kernel_tuning("tpu9000")


@pytest.mark.parametrize("b,s,w,chunk,blk_w", [
    (2, 64, 32, 16, 32),
    (1, 128, 64, 32, 32),
    (3, 32, 16, 16, 16),
])
def test_rglru_scan_sweep(b, s, w, chunk, blk_w):
    from repro.kernels.rglru_scan import rglru_scan
    rng = jax.random.PRNGKey(s + w)
    ks = jax.random.split(rng, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w)))
    bb = jax.random.normal(ks[1], (b, s, w))
    got = rglru_scan(a, bb, chunk=chunk, blk_w=blk_w, interpret=True)
    want = ref.ref_rglru_scan(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
