"""Scheduler behaviour (§4.1): FCFS online, preemption, SLO shedding,
KV-aware offline selection."""

from repro.core.block_manager import BlockManager
from repro.core.estimator import TimeModel
from repro.core.policies import BS, ECHO
from repro.core.radix_pool import OfflinePool
from repro.core.request import SLO, Request, RequestState, TaskType
from repro.core.scheduler import Scheduler


def _sched(policy=ECHO, num_blocks=64, bs=4, chunk=8, tm=None, **kw):
    pool = OfflinePool(bs)
    bm = BlockManager(num_blocks, bs, task_aware=policy.task_aware_kv,
                      rc_provider=pool.rc)
    tm = tm or TimeModel(alpha=0, beta=1e-3, c=1e-4, gamma=1e-4, delta=1e-4,
                         d0=1e-4, lam=1.0)
    return Scheduler(bm, pool, tm, policy, chunk_size=chunk, **kw)


def _online(plen, t=0.0, slo=SLO(1.0, 0.1), max_new=4):
    return Request(prompt=tuple(range(plen)), max_new_tokens=max_new,
                   task_type=TaskType.ONLINE, arrival_time=t, slo=slo)


def _offline(prompt, t=0.0, max_new=4):
    return Request(prompt=tuple(prompt), max_new_tokens=max_new,
                   task_type=TaskType.OFFLINE, arrival_time=t)


def test_online_admitted_fcfs():
    s = _sched()
    r1, r2 = _online(8, 0.0), _online(8, 0.1)
    s.submit(r2)
    s.submit(r1)   # submitted out of order but queue preserves submit order
    plan = s.schedule(0.2)
    reqs = [r for r, _ in plan.prefills]
    assert reqs == [r2, r1]      # FCFS on queue order


def test_offline_only_after_online_drained():
    s = _sched(max_running=1)
    s.submit(_online(8))
    s.submit(_offline(range(100, 116)))
    plan = s.schedule(0.0)
    # max_running=1: the online request fills the slot; online queue empty,
    # but no offline slot left
    assert all(r.task_type == TaskType.ONLINE for r, _ in plan.prefills)


def test_online_preempts_offline_on_memory_pressure():
    s = _sched(num_blocks=8, chunk=32)
    off = _offline(range(100, 124))            # 24 tokens -> 6 blocks
    s.submit(off)
    plan = s.schedule(0.0)
    assert any(r is off for r, _ in plan.prefills)
    assert len(off.block_ids) == 6             # 2 blocks free
    on = _online(24, t=1.0)                    # needs 6 blocks: must preempt
    s.submit(on)
    plan = s.schedule(1.0)
    assert off in plan.preempted
    assert off.state == RequestState.WAITING
    assert any(r is on for r, _ in plan.prefills)
    assert len(s.pool) == 1                    # offline back in pool


def test_slo_sheds_offline_work():
    # estimator on; make decode so slow the offline prefill would violate SLO
    tm = TimeModel(alpha=0, beta=1.0, c=0.5, gamma=1e-4, delta=1e-4,
                   d0=1e-4, lam=1.0)           # prefill ~1s/token!
    s = _sched(policy=ECHO, tm=tm)
    on = _online(4, slo=SLO(ttft=1.0, tpot=0.05))
    s.submit(on)
    plan = s.schedule(0.0)                     # online prefill admitted
    for r, c in plan.prefills:
        r.computed_tokens += c
    on.record_token(1, 0.5)
    s.submit(_offline(range(100, 132)))
    plan = s.schedule(0.5)
    # the offline prefill would add ~8s >> tpot budget: must be shed
    assert all(r.task_type == TaskType.ONLINE for r, _ in plan.prefills)
    assert on in plan.decodes


def test_slo_shed_rolls_back_chunk_allocation():
    """Regression: shedding an offline prefill must release the chunk's
    freshly allocated blocks back to the computed-token boundary —
    otherwise the shed request keeps holding memory for work it will not
    do this iteration, starving same-iteration admission."""
    tm = TimeModel(alpha=0, beta=0.08, c=1e-4, gamma=1e-4, delta=1e-4,
                   d0=1e-4, lam=1.0)           # prefill chunk of 8 = 0.64s
    s = _sched(policy=ECHO, tm=tm)
    on = _online(4, t=0.0, slo=SLO(ttft=1.0, tpot=0.05))
    s.submit(on)
    plan = s.schedule(0.0)                     # online prefill alone
    for r, c in plan.prefills:
        r.computed_tokens += c
        s.bm.commit(r, r.full_tokens, 0.0)
    on.record_token(1, 0.05)                   # next deadline: 1.05s
    off = _offline(range(100, 132))
    s.submit(off)
    plan = s.schedule(0.2)                     # loose budget: admitted
    assert any(r is off for r, _ in plan.prefills)
    for r, c in plan.prefills:
        r.computed_tokens += c
        s.bm.commit(r, r.full_tokens, 0.2)
    assert off.computed_tokens == 8
    free_before = s.bm.free_blocks
    held_before = len(off.block_ids)
    plan = s.schedule(0.9)                     # 0.135s budget << 0.64s chunk
    # the offline continuation chunk is shed...
    assert not any(r is off for r, _ in plan.prefills)
    assert on in plan.decodes
    # ...and its freshly allocated blocks are rolled back
    bs = s.bm.block_size
    want_blocks = (off.computed_tokens + bs - 1) // bs
    assert len(off.block_ids) == want_blocks, \
        "shed chunk's blocks must be rolled back to the computed boundary"
    assert len(off.block_ids) == held_before
    assert s.bm.free_blocks >= free_before


def test_preempted_offline_keeps_fcfs_priority():
    """Regression: a preempted offline request re-enters the pool at the
    tail of its bucket's OrderedDict, but candidate selection must still
    honour (arrival_time, rid) — repeated preemption must not starve it
    behind newer arrivals."""
    s = _sched(policy=ECHO, num_blocks=64, chunk=8)
    old = _offline(tuple(range(100, 116)), t=0.0)
    s.pool.add(old)
    s.pool.remove(old)                         # admitted...
    newer = _offline(tuple(range(200, 216)), t=1.0)
    s.pool.add(newer)
    s.pool.add(old)                            # ...then preempted: re-added
    cands = list(s.pool.candidates())
    assert cands[0] is old, \
        "pool candidates must respect arrival order, not re-add order"
    plan = s.schedule(2.0)
    first_off = [r for r, _ in plan.prefills if r.task_type == TaskType.OFFLINE]
    assert first_off and first_off[0] is old


def test_kv_aware_prefers_cached_candidate():
    s = _sched(policy=ECHO, num_blocks=64, chunk=8)
    doc = tuple(range(16))
    leader = _offline(doc + (100, 101, 102, 103), t=0.0)
    stranger = _offline(tuple(range(200, 220)), t=0.0)
    s.submit(leader)
    s.submit(stranger)
    # leader admitted + fully prefilled + committed
    plan = s.schedule(0.0)
    assert any(r is leader or r is stranger for r, _ in plan.prefills)
    admitted = plan.prefills[0][0]
    while not admitted.prefill_done:
        for r, c in list(plan.prefills):
            r.computed_tokens += c
            s.bm.commit(r, r.full_tokens, 0.0)
        plan = s.schedule(1.0)
    # now submit a follower sharing the doc: must be chosen over FCFS order
    follower = _offline(doc + (300, 301, 302, 303), t=5.0)
    earlier_stranger = _offline(tuple(range(400, 420)), t=4.0)
    s.submit(earlier_stranger)
    s.submit(follower)
    for _ in range(8):
        plan = s.schedule(2.0)
        newly = [r for r, _ in plan.prefills if r in (follower, earlier_stranger)]
        if newly:
            break
        for r, c in list(plan.prefills):
            r.computed_tokens += c
            s.bm.commit(r, r.full_tokens, 2.0)
    assert newly and newly[0] is follower, \
        "KV-aware scheduler must pick the prefix-sharing candidate first"


def test_fcfs_policy_ignores_cache_affinity():
    s = _sched(policy=BS, num_blocks=64, chunk=8)
    a = _offline(tuple(range(16)), t=0.0)
    b = _offline(tuple(range(50, 66)), t=1.0)
    s.submit(b)
    s.submit(a)
    plan = s.schedule(0.0)
    first = [r for r, _ in plan.prefills]
    assert first and first[0] is a            # earliest arrival


def test_benefit_counts_cached_progress():
    s = _sched(policy=ECHO)
    doc = tuple(range(16))
    leader = _offline(doc + (1, 2, 3, 4))
    s.submit(leader)
    plan = s.schedule(0.0)
    for r, c in plan.prefills:
        r.computed_tokens += c
        s.bm.commit(r, r.full_tokens, 0.0)
    follower = _offline(doc + (7, 8, 9, 10))
    cand = s._evaluate_candidate(follower, plan)
    assert cand.cached >= 8
    assert cand.d_benefit >= cand.cached
