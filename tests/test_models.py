"""Model-zoo correctness: incremental decode == full forward, sliding-window
ring semantics, M-RoPE, MoE routing invariants, SSD chunked == sequential."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.models.common import rope_angles
from repro.models.moe import _route
from repro.models.ssm import ssd_chunked
from repro.kernels.ref import ref_ssd_sequential


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_train(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    b, s = 2, 33
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    full = m.forward_train(params, toks)
    p = s - 1
    last, cache = m.prefill(params, toks[:, :p])
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, p - 1]),
                               rtol=2e-4, atol=2e-4)
    cache = m.pad_cache(cache, p, 64)
    lg, _ = m.decode_step(params, toks[:, p], cache,
                          jnp.full((b,), p, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, p]),
                               rtol=2e-3, atol=2e-3)


def test_multistep_decode_consistency(tiny_model):
    """Decode 8 tokens step by step == teacher-forced full forward."""
    m, params = tiny_model
    rng = jax.random.PRNGKey(3)
    b, p, extra = 2, 17, 8
    toks = jax.random.randint(rng, (b, p + extra), 0, m.cfg.vocab_size)
    full = m.forward_train(params, toks)
    last, cache = m.prefill(params, toks[:, :p])
    cache = m.pad_cache(cache, p, p + extra + 1)
    for i in range(extra):
        pos = jnp.full((b,), p + i, jnp.int32)
        lg, cache = m.decode_step(params, toks[:, p + i], cache, pos)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, p + i]),
                                   rtol=3e-3, atol=3e-3)


def test_sliding_window_ring_matches_window_attention():
    """Ring-buffer decode == train-mode window-masked attention, step by
    step, once the context exceeds the window (wrap-around exercised)."""
    import dataclasses
    cfg = dataclasses.replace(
        get_config("recurrentgemma-9b").reduced(), window=8)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, total, w = 1, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, total), 0,
                              cfg.vocab_size)
    full = m.forward_train(params, toks)          # window-masked attention
    cache = m.make_cache(b, total)                # attn entries sized to w
    for i in range(total):
        pos = jnp.full((b,), i, jnp.int32)
        lg, cache = m.decode_step(params, toks[:, i], cache, pos)
        if i < total - 1:
            np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                       rtol=3e-3, atol=3e-3)


def test_mrope_text_equals_rope():
    hd, theta = 32, 10_000.0
    pos = jnp.arange(12)[None]
    c1, s1 = rope_angles(pos, hd, theta)
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 12))
    c2, s2 = rope_angles(pos3, hd, theta, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_moe_route_respects_capacity_and_weights():
    rng = jax.random.PRNGKey(0)
    n, g, e, k, cap = 2, 16, 4, 2, 6
    gates = jax.nn.softmax(jax.random.normal(rng, (n, g, e)), -1)
    dispatch, combine = _route(gates, k, cap)
    # <= capacity tokens per expert slot; one token per (expert, slot)
    assert float(jnp.max(jnp.sum(dispatch, axis=1))) <= 1.0 + 1e-6
    # each token dispatched at most k times
    per_tok = jnp.sum(dispatch, axis=(2, 3))
    assert float(jnp.max(per_tok)) <= k + 1e-6
    # combine weights normalized over selected experts (sum to 1 when kept)
    w = jnp.sum(combine, axis=(2, 3))
    kept = per_tok >= k - 1e-6
    np.testing.assert_allclose(np.asarray(w[kept]), 1.0, rtol=1e-5)


def test_ssd_chunked_matches_sequential():
    rng = jax.random.PRNGKey(2)
    ks = jax.random.split(rng, 4)
    b, s, h, p, n = 2, 96, 3, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dta = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    y1, f1 = ssd_chunked(x, dta, bm, cm, chunk=16)
    y2, f2 = ref_ssd_sequential(x, dta, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_initial_state():
    rng = jax.random.PRNGKey(4)
    ks = jax.random.split(rng, 5)
    b, s, h, p, n = 1, 32, 2, 4, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dta = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    # split into two halves with carried state == full run
    y_full, f_full = ssd_chunked(x, dta, bm, cm, chunk=16)
    y1, f1 = ssd_chunked(x[:, :16], dta[:, :16], bm[:, :16], cm[:, :16], chunk=16)
    y2, f2 = ssd_chunked(x[:, 16:], dta[:, 16:], bm[:, 16:], cm[:, 16:],
                         chunk=16, initial_state=f1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full),
                               rtol=2e-4, atol=2e-4)


def test_flash_context_matches_naive(tiny_cfg):
    """Blockwise (flash) context attention == naive path, incl. window and
    right-padding masks."""
    import repro.models.attention as A
    from repro.models.common import default_positions, rope_angles
    p = A.attn_init(jax.random.PRNGKey(0), tiny_cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, tiny_cfg.d_model))
    cos, sin = rope_angles(default_positions(2, 128), tiny_cfg.head_dim,
                           tiny_cfg.rope_theta)
    naive, _ = A.attn_context(p, tiny_cfg, x, cos, sin, window=40,
                              seq_lens=jnp.array([100, 64]))
    old_t, old_b = A.FLASH_THRESHOLD, A.FLASH_BLOCK
    try:
        A.FLASH_THRESHOLD, A.FLASH_BLOCK = 64, 32
        flash, _ = A.attn_context(p, tiny_cfg, x, cos, sin, window=40,
                                  seq_lens=jnp.array([100, 64]))
    finally:
        A.FLASH_THRESHOLD, A.FLASH_BLOCK = old_t, old_b
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)
