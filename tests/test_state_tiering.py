"""State-family tiered memory: device->host->device snapshot round trips
must be bit-exact (same tokens with the swap tier on and off) on both the
pure-SSM (mamba2) and hybrid (recurrentgemma) paths, abort-after-preempt
must release parked snapshot slots, and the byte-denominated estimator
terms must behave across families (mixed-payload fit_swap, perturbed
pass-through)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ECHO, SLO, EchoEngine, Request, TaskType, TimeModel
from repro.core.block_io import (KV_BYTES_PER_TOKEN_8B, io_spec_for_model,
                                 paged_spec, state_spec)
from repro.core.simulator import clone_requests
from repro.models import Model
from repro.serving import EchoService, HandleStatus

STATE_ARCHS = ("mamba2-1.3b", "recurrentgemma-9b")


def _state_model(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kinds = set(cfg.attn_layers)
    bs = cfg.ssm_chunk if kinds == {"ssm"} else 16
    return cfg, model, params, bs


def _tiering_workload(cfg, bs, seed=3):
    """One shared document + pooled questions (the doc's snapshots keep
    rc > 0 while any question is pending) and an online burst sized to
    flush the doc off the tight device pool mid-run."""
    rng = np.random.default_rng(seed)
    doc = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 3 * bs))
    reqs = []
    for i in range(6):
        q = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 7))
        reqs.append(Request(prompt=doc + q, max_new_tokens=4,
                            task_type=TaskType.OFFLINE))
    for i in range(3):
        p = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 3 * bs))
        reqs.append(Request(prompt=p, max_new_tokens=4,
                            task_type=TaskType.ONLINE,
                            arrival_time=0.0004 * (i + 1),
                            slo=SLO(30.0, 5.0)))
    return reqs


def _run(model, params, bs, reqs, host_blocks):
    eng = EchoEngine(model, params, ECHO, num_blocks=8, block_size=bs,
                     chunk_size=2 * bs, max_pages_per_seq=16,
                     max_running=2, host_kv_blocks=host_blocks)
    for r in clone_requests(reqs, preserve_rid=True):
        eng.submit(r)
    stats = eng.run(max_iters=2000)
    toks = {r.rid: list(r.output_tokens) for r in stats.finished}
    return eng, stats, toks


@pytest.mark.parametrize("arch", STATE_ARCHS)
def test_state_tier_roundtrip_bit_exact(arch):
    """Snapshots evicted to the host tier and restored over the (virtual)
    link must resume generation bit-exactly: the swap-on run emits the SAME
    tokens as the recompute-only run, while actually moving snapshot
    traffic both ways."""
    cfg, model, params, bs = _state_model(arch)
    reqs = _tiering_workload(cfg, bs)
    eng_off, stats_off, toks_off = _run(model, params, bs, reqs, 0)
    eng_on, stats_on, toks_on = _run(model, params, bs, reqs, 32)
    assert eng_on.bm.io.family == "state"
    assert len(toks_on) == len(reqs)
    assert toks_on == toks_off, \
        "host-tier round trips must not change generated tokens"
    assert eng_on.bm.metrics.swapped_out_tokens > 0, \
        "scenario must park snapshots on the host tier"
    assert eng_on.bm.metrics.swapped_in_tokens > 0, \
        "scenario must restore snapshots from the host tier"
    assert eng_on.bm.metrics.swapped_in_bytes > 0
    assert eng_on.bm.metrics.swapped_out_bytes > 0
    # a restore moves at most one fixed-size snapshot per swapped-in block
    per_block = eng_on.bm.io.block_bytes(bs)
    assert eng_on.bm.metrics.swapped_out_bytes % per_block == 0
    assert stats_on.slo_attainment("ttft") >= stats_off.slo_attainment("ttft")
    assert stats_on.offline_throughput() >= stats_off.offline_throughput(), \
        "snapshot restore must not lose to recompute-only"


@pytest.mark.parametrize("arch", STATE_ARCHS)
def test_state_restore_priced_as_one_snapshot(arch):
    """The scheduler's swap-in price for a state-family prefix is ONE
    fixed-size snapshot regardless of prefix depth (restore_last_only) —
    never the per-token paged price."""
    cfg, model, params, bs = _state_model(arch)
    eng = EchoEngine(model, params, ECHO, num_blocks=8, block_size=bs,
                     chunk_size=2 * bs, max_pages_per_seq=16,
                     host_kv_blocks=8)
    sched = eng.scheduler
    one = eng.bm.io.block_bytes(bs)
    assert sched._restore_bytes(bs) == one
    assert sched._restore_bytes(4 * bs) == one, \
        "restore needs only the last boundary snapshot"
    assert one != paged_spec().restore_bytes(bs, bs), \
        "a snapshot must not be priced like a KV page run"


def test_abort_preempted_state_request_releases_snapshot_slots():
    """Leak check: aborting a preempted state-family request must release
    its parked host snapshot slots and device pins — mirrored from the
    paged abort test, over the StateRunner protocol."""
    from test_serving import assert_no_block_leaks, assert_no_owner_pin_leaks

    cfg, model, params, bs = _state_model("mamba2-1.3b")
    rng = np.random.default_rng(9)
    eng = EchoEngine(model, params, ECHO, num_blocks=8, block_size=bs,
                     chunk_size=2 * bs, max_pages_per_seq=16,
                     max_running=2, host_kv_blocks=32)
    service = EchoService(eng)
    doc = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 3 * bs))
    offs = [service.submit(
        doc + tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 7)),
        task_type="offline", max_new_tokens=24) for _ in range(4)]
    for i in range(3):
        service.submit(
            tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 3 * bs)),
            task_type="online", max_new_tokens=6,
            slo=SLO(30.0, 5.0), arrival_time=0.0004 * (i + 1))
    victim = None
    for _ in range(400):
        victim = next((h for h in offs
                       if h.status is HandleStatus.PREEMPTED
                       and h.request.owner_pins), None)
        if victim is not None:
            break
        if not service.step():
            break
    assert victim is not None, "no preemption left owner pins behind"
    pins = list(victim.request.owner_pins)
    assert victim.abort()
    assert victim.request.owner_pins == []
    for h in pins:
        bid = eng.bm.hash_to_bid.get(h)
        if bid is not None:
            assert eng.bm.blocks[bid].unfinished_owners == 0
        hb = eng.bm.host.get(h)
        if hb is not None:
            assert hb.unfinished_owners == 0
    assert victim.request.rid not in eng.runner.live, \
        "abort must drop the live decode state"
    assert_no_block_leaks(eng)
    service.run()
    # the burst leaves stragglers parked behind the online memory reserve;
    # abort them too — every abort must scrub its pins from BOTH tiers
    for h in offs:
        if not h.done:
            h.abort()
    service.run()
    assert all(h.done for h in offs)
    assert_no_block_leaks(eng)
    assert_no_owner_pin_leaks(eng)


# --------------------------------------------------- byte-term estimators
def test_fit_swap_mixed_payloads_recovers_link_rate():
    """KV-page and snapshot transfers land in ONE byte-denominated pool:
    a fit over their mix recovers the link rate that generated both."""
    true_byte, true_floor = 1.0 / (20.0 * 1e9), 8e-5
    samples = []
    snap = state_spec(83_456).block_bytes_fixed
    for n_tok in (16, 48, 96, 256):                # paged restores
        n = n_tok * KV_BYTES_PER_TOKEN_8B
        samples.append((n, true_byte * n + true_floor))
    for k in (1, 2, 3, 5):                         # snapshot restores
        n = k * snap
        samples.append((n, true_byte * n + true_floor))
    tm = TimeModel.a100()
    tm.fit_swap(samples)
    assert tm.swap_byte == pytest.approx(true_byte, rel=1e-6)
    assert tm.swap_floor == pytest.approx(true_floor, rel=1e-6)
    for n, t in samples:
        assert tm.swap_time(n) == pytest.approx(t, rel=1e-6)


def test_perturbed_model_passes_byte_terms_through():
    base = TimeModel.a100()
    pm = base.perturbed(scale=2.0)
    for n in (131_072, 83_456, 7 * KV_BYTES_PER_TOKEN_8B):
        assert pm.swap_time(n) == pytest.approx(2.0 * base.swap_time(n))
    assert pm.swap_time(0) == 0.0


def test_io_spec_families(tiny_cfg):
    """io_spec_for_model: attention models price per token, state models
    one fixed snapshot per block (restore_last_only)."""
    m = Model(tiny_cfg)
    io = io_spec_for_model(m)
    assert io.family == "paged" and not io.restore_last_only
    assert io.restore_bytes(32, 16) == 32 * io.bytes_per_token
    for arch in STATE_ARCHS:
        cfg, model, params, bs = _state_model(arch)
        sio = io_spec_for_model(model)
        assert sio.family == "state" and sio.restore_last_only
        assert sio.block_bytes_fixed == model.cache_bytes(
            1, 1 if set(cfg.attn_layers) == {"ssm"} else max(cfg.window, 1))
        assert sio.restore_bytes(8 * bs, bs) == sio.block_bytes_fixed
