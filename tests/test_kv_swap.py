"""Tiered KV cache: host swap tier semantics, the eviction-order peek
regression (scheduler punishment vs. realized evictions), swap-vs-recompute
decisions, abort hygiene across tiers, and PagedRunner round-trip
bit-exactness."""
import jax
import numpy as np
import pytest

from repro.core import (ECHO, SLO, EchoEngine, Request, TaskType, TimeModel)
from repro.core.block_manager import BlockManager, chain_hash
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.serving import EchoService, HandleStatus


def _req(tokens, task=TaskType.OFFLINE, max_new=4):
    r = Request(prompt=tuple(tokens), max_new_tokens=max_new, task_type=task)
    r.admit()
    return r


def _fill(bm, tokens, now, task=TaskType.OFFLINE):
    """Allocate + commit + free one request covering ``tokens``; returns it."""
    r = _req(tokens, task)
    assert bm.allocate(r, len(tokens), r.full_tokens, now) is not None
    r.computed_tokens = len(tokens)
    bm.commit(r, r.full_tokens, now)
    return r


# ------------------------------------------------------ eviction-order peek
def test_peek_matches_realized_eviction_order_after_churn():
    """Regression (satellite 1): the scheduler's punishment peek used to
    sort its own copy of evictable blocks while eviction popped a lazily
    invalidated heap — after ref/unref churn the two could disagree. Both
    now share ``peek_eviction_order``; this locks peeked == realized."""
    rc_map = {}
    bm = BlockManager(32, 4, rc_provider=lambda h: rc_map.get(h, 0))
    rng = np.random.default_rng(7)
    live = []
    now = 0.0
    for i in range(20):
        now += 1.0
        task = TaskType.ONLINE if i % 3 == 0 else TaskType.OFFLINE
        toks = tuple(int(x) for x in rng.integers(0, 50, 8))
        r = _req(toks, task)
        if bm.allocate(r, len(toks), r.full_tokens, now) is None:
            continue
        r.computed_tokens = len(toks)
        bm.commit(r, r.full_tokens, now)
        live.append(r)
        # churn: free some (finished and unfinished), re-reference others
        # via prefix hits, and shuffle rc so heap entries go stale
        if len(live) > 2 and i % 2 == 0:
            bm.free_request(live.pop(0), now + 0.1,
                            finished=bool(rng.integers(0, 2)))
        if live and i % 5 == 0:
            peer = _req(live[0].prompt)
            if bm.allocate(peer, len(peer.prompt), peer.full_tokens,
                           now + 0.2) is not None:
                bm.free_request(peer, now + 0.3, finished=True)
        for h in list(rc_map) + [chain_hash(0, toks[:4])]:
            rc_map[h] = int(rng.integers(0, 4))
    for r in live:
        bm.free_request(r, now + 1.0, finished=True)

    n = bm.evictable_count()
    assert n >= 5, "churn scenario must leave a non-trivial evictable set"
    want = [b.bid for b in bm.peek_eviction_order(n)]
    got = []
    while True:
        bid = bm._evict_one()
        if bid is None:
            break
        got.append(bid)
    assert got == want, "peeked eviction order diverged from realized order"


def test_peek_is_read_only():
    rc_map = {}
    bm = BlockManager(8, 4, rc_provider=lambda h: rc_map.get(h, 0))
    for i in range(3):
        r = _fill(bm, range(i * 10, i * 10 + 8), float(i))
        bm.free_request(r, float(i) + 0.5, finished=True)
    before = (bm.free_blocks, bm.cached_blocks, dict(bm.hash_to_bid))
    bm.peek_eviction_order(3)
    assert (bm.free_blocks, bm.cached_blocks, dict(bm.hash_to_bid)) == before


# ------------------------------------------------------------ host tier core
def test_eviction_swaps_reusable_block_to_host():
    rc_map = {}
    bm = BlockManager(1, 4, rc_provider=lambda h: rc_map.get(h, 0),
                      host_blocks=4)
    r1 = _fill(bm, range(4), 0.0)
    h = chain_hash(0, (0, 1, 2, 3))
    rc_map[h] = 2                          # future reuse: swap, don't drop
    bm.free_request(r1, 1.0, finished=True)
    r2 = _fill(bm, (9, 9, 9, 9), 2.0)      # forces the eviction
    assert h not in bm.hash_to_bid
    assert h in bm.host, "future-needed block must be swapped, not dropped"
    assert bm.metrics.swapped_out_blocks == 1
    assert bm.metrics.swapped_out_tokens == 4
    assert bm.metrics.punished_tokens == 0, \
        "a swapped block is preserved — no recompute punishment"
    events = bm.drain_swap_events()
    assert [(k, hb.hash) for k, _, hb in events] == [("out", h)]

    # and the prefix is restorable: probe + swap_in round trip
    bm.free_request(r2, 3.0, finished=True)
    r3 = _req(range(8))
    assert bm.probe_host_prefix(r3.full_tokens, 0) == 4
    got = bm.swap_in(r3, r3.full_tokens, 4.0, 4)
    assert got == 4
    assert h in bm.hash_to_bid and h not in bm.host
    assert r3.block_ids and bm.blocks[r3.block_ids[0]].ref == 1
    assert bm.metrics.swapped_in_tokens == 4
    assert [(k, hb.hash) for k, _, hb in bm.drain_swap_events()] \
        == [("in", h)]


def test_dead_block_is_dropped_not_swapped():
    bm = BlockManager(1, 4, rc_provider=lambda h: 0, host_blocks=4)
    r1 = _fill(bm, range(4), 0.0)
    bm.free_request(r1, 1.0, finished=True)     # rc == 0: dead offline
    _fill(bm, (9, 9, 9, 9), 2.0)
    assert len(bm.host) == 0, "dead blocks must not waste host capacity"
    assert bm.metrics.swapped_out_blocks == 0


def test_host_tier_capacity_evicts_lowest_priority():
    rc_map = {}
    bm = BlockManager(1, 4, rc_provider=lambda h: rc_map.get(h, 0),
                      host_blocks=1)
    r1 = _fill(bm, range(4), 0.0)
    h_low = chain_hash(0, (0, 1, 2, 3))
    rc_map[h_low] = 1
    bm.free_request(r1, 1.0, finished=True)
    r2 = _fill(bm, (7, 7, 7, 7), 2.0)           # evicts -> swaps h_low out
    h_high = chain_hash(0, (7, 7, 7, 7))
    rc_map[h_high] = 5
    bm.free_request(r2, 3.0, finished=True)
    r3 = _fill(bm, (8, 8, 8, 8), 4.0)           # evicts -> h_high displaces
    assert h_high in bm.host and h_low not in bm.host
    # a lower-priority candidate bounces off a full tier of better blocks
    bm.free_request(r3, 5.0, finished=True)     # rc 0: dropped on eviction
    r4 = _fill(bm, (6, 6, 6, 6), 5.5)
    rc_map[chain_hash(0, (6, 6, 6, 6))] = 1
    bm.free_request(r4, 6.0, finished=True)
    _fill(bm, (5, 5, 5, 5), 7.0)
    assert h_high in bm.host, "high-priority resident must survive"
    assert h_low not in bm.host and chain_hash(0, (6, 6, 6, 6)) not in bm.host
    assert bm.metrics.host_bounced_blocks >= 1


# ------------------------------------------------- scheduler swap decisions
def _sim_engine(host_blocks, tm=None, num_blocks=96, **kw):
    return EchoEngine(None, None, ECHO, num_blocks=num_blocks, block_size=16,
                      chunk_size=64, time_model=tm or TimeModel.a100(),
                      host_kv_blocks=host_blocks, **kw)


def _burst_workload(seed=3, duration=30.0):
    # offline prefix working set (8 docs x 16 blocks) over a 96-block device
    # budget: online bursts flush it, the regime where swap matters
    trace = BurstyTrace(base_rate=2.0, burst_rate=10.0, burst_len=6.0,
                        burst_prob=0.1, tidal_period=4 * duration, seed=seed)
    online = make_online_requests(trace.sample(0, duration), prompt_mean=128,
                                  prompt_std=32, max_new_mean=16,
                                  slo=SLO(1.0, 0.1), seed=seed + 1)
    offline = make_offline_corpus(8, 48, doc_len=256, question_len=24,
                                  max_new=8, seed=seed + 2)
    return online + offline


def test_swap_enabled_engine_reduces_punishment():
    res = {}
    for host in (0, 256):
        eng = _sim_engine(host)
        for r in _burst_workload():
            eng.submit(r)
        stats = eng.run(max_iters=60_000, until_time=200.0)
        res[host] = (eng, stats)
    eng0, st0 = res[0]
    eng1, st1 = res[256]
    assert len(st0.finished) == len(st1.finished)
    assert eng1.bm.metrics.swapped_in_tokens > 0, "swap path never exercised"
    assert st1.swapped_in_tokens == eng1.bm.metrics.swapped_in_tokens
    assert eng1.bm.metrics.punished_tokens < eng0.bm.metrics.punished_tokens
    assert st1.offline_throughput() >= st0.offline_throughput(), \
        "host tier must not lose offline throughput on the burst workload"


def test_swap_in_rejected_when_transfer_loses_to_recompute():
    """The decision is priced, not assumed: with a pathologically slow link
    the scheduler must keep recomputing rather than swap in."""
    slow = TimeModel.a100(swap_byte=1e-3)     # ~131 s/token: PCIe from hell
    eng = _sim_engine(256, tm=slow)
    for r in _burst_workload():
        eng.submit(r)
    eng.run(max_iters=60_000, until_time=200.0)
    assert eng.bm.metrics.swapped_out_tokens > 0, \
        "swap-out is free at eviction time and must still happen"
    assert eng.bm.metrics.swapped_in_tokens == 0, \
        "a transfer that loses to recompute must never be chosen"


def test_swap_charged_against_slo_budget():
    """Plans carrying swap traffic must price it. On the serial clock
    (overlap off) est_time adds the full PCIe term; under overlap only the
    exposed transfer tail plus the launch overhead is charged — never more
    than the serial price, never less than compute alone."""
    from repro.core.scheduler import Plan
    eng = _sim_engine(256, tm=TimeModel.a100(swap_overlap=False))
    sched = eng.scheduler
    r = _req(range(64))
    plan = Plan(prefills=[(r, 32)], swap_ins=[(r, 32)])
    with_swap = sched._estimate(plan)
    plan2 = Plan(prefills=[(r, 32)])
    without = sched._estimate(plan2)
    link = eng.tm.swap_time(sched._restore_bytes(32))
    assert with_swap == pytest.approx(without + link)

    eng = _sim_engine(256)                    # overlap on by default
    sched = eng.scheduler
    plan = Plan(prefills=[(r, 32)], swap_ins=[(r, 32)])
    overlapped = sched._estimate(plan)
    compute = sched._estimate(Plan(prefills=[(r, 32)]))
    link = eng.tm.swap_time(sched._restore_bytes(32))
    assert overlapped == pytest.approx(
        eng.tm.overlapped_iteration_time(compute, link))
    assert compute < overlapped <= compute + link


# ------------------------------------------------------- abort across tiers
def test_abort_preempted_request_releases_host_and_device_pins():
    """Satellite: abort of a request with swapped-out blocks must free both
    tiers — no unfinished-owner pin may outlive its owner."""
    from test_serving import assert_no_block_leaks, assert_no_owner_pin_leaks

    eng = _sim_engine(64, num_blocks=20)
    service = EchoService(eng)
    doc = tuple(range(500, 596))
    offs = [service.submit(doc + tuple(range(700 + 9 * i, 708 + 9 * i)),
                           task_type="offline", max_new_tokens=40)
            for i in range(2)]
    for i in range(3):
        service.submit(tuple(range(i * 70, i * 70 + 60)),
                       task_type="online", max_new_tokens=12,
                       slo=SLO(10.0, 1.0), arrival_time=0.01 * (i + 1))
    victim = None
    for _ in range(400):
        victim = next((h for h in offs
                       if h.status is HandleStatus.PREEMPTED
                       and h.request.owner_pins), None)
        if victim is not None:
            break
        if not service.step():
            break
    assert victim is not None, "no preemption left owner pins behind"
    pins = list(victim.request.owner_pins)
    assert victim.abort()
    assert victim.request.owner_pins == []
    for h in pins:
        bid = eng.bm.hash_to_bid.get(h)
        if bid is not None:
            assert eng.bm.blocks[bid].unfinished_owners == 0
        hb = eng.bm.host.get(h)
        if hb is not None:
            assert hb.unfinished_owners == 0
    assert_no_block_leaks(eng)
    service.run()
    assert_no_block_leaks(eng)
    assert_no_owner_pin_leaks(eng)


def test_drained_swap_engine_has_no_pins_or_leaks():
    from test_serving import assert_no_block_leaks, assert_no_owner_pin_leaks

    eng = _sim_engine(128)
    service = EchoService(eng)
    stats = service.drive(_burst_workload(seed=11), max_iters=60_000,
                          until_time=200.0)
    assert stats.finished, "workload must complete"
    assert_no_block_leaks(eng)
    assert_no_owner_pin_leaks(eng)


# --------------------------------------------------- real-runner round trip
@pytest.fixture(scope="module")
def paged(tiny_cfg):
    from repro.models import Model
    from repro.models.paged import PagedRunner
    m = Model(tiny_cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params, PagedRunner(m, params, num_pages=16, page_size=8,
                                  max_pages_per_seq=8, chunk_size=16)


def _flatten_pages(pages):
    out = []
    for seg in pages:
        for pg in seg:
            out.append(np.asarray(pg["k"]))
            out.append(np.asarray(pg["v"]))
    return out


def test_paged_runner_swap_roundtrip_is_bit_exact(paged):
    """Satellite: device->host->device staging must restore the KV pages
    bit-for-bit — swapped state is a cache tier, not an approximation."""
    model, params, runner = paged
    toks = list(range(16))
    runner.prefill_chunk(toks, 0, [1, 2])
    before = _flatten_pages(runner.pages)

    payload = runner.read_block(1)
    zeros = [[{k: np.zeros_like(v) for k, v in pg.items()} for pg in seg]
             for seg in payload]
    runner.write_block(1, zeros)
    assert any(not np.array_equal(a, b) for a, b in
               zip(before, _flatten_pages(runner.pages))), \
        "zeroing block 1 must visibly change the page pool"

    runner.write_block(1, payload)
    after = _flatten_pages(runner.pages)
    for a, b in zip(before, after):
        assert np.array_equal(a, b), "swap round trip must be bit-exact"


def test_swap_restore_preserves_outputs(paged):
    """End-to-end: force preemption, eviction-to-host, and swap-restore on
    a real model; every request must still generate the dense-reference
    greedy tokens (restored KV feeds attention exactly as computed KV
    would)."""
    from test_engine import _reference_generate

    model, params = paged[0], paged[1]
    rng = np.random.default_rng(2)
    vocab = model.cfg.vocab_size
    offp = tuple(int(x) for x in rng.integers(0, vocab, 56))   # 7 blocks
    onp = tuple(int(x) for x in rng.integers(0, vocab, 88))    # 11 blocks
    off = Request(prompt=offp, max_new_tokens=6, task_type=TaskType.OFFLINE)
    eng = EchoEngine(model, params, ECHO, num_blocks=16, block_size=8,
                     chunk_size=16, max_pages_per_seq=16,
                     host_kv_blocks=32)
    eng.submit(off)
    for _ in range(3):             # commit a few of off's prefill chunks
        eng.step()
    assert off.computed_tokens >= 32
    # an online arrival that cannot fit beside off's blocks: off is
    # preempted, its committed (rc>0: it sits in the pool) blocks are
    # evicted under memory pressure and swapped to the host tier
    on = Request(prompt=onp, max_new_tokens=12, task_type=TaskType.ONLINE,
                 arrival_time=eng.now, slo=SLO(10, 10))
    eng.submit(on)
    eng.run(max_iters=1000)
    assert off.done and on.done
    assert off.n_preemptions >= 1, "scenario must preempt the offline req"
    assert eng.bm.metrics.swapped_out_tokens > 0, \
        "preempted KV must be parked on the host tier"
    assert eng.bm.metrics.swapped_in_tokens > 0, \
        "scenario must actually exercise the swap-restore path"
    assert off.output_tokens == _reference_generate(model, params, offp, 6), \
        "restored KV diverged from computed KV"
    assert on.output_tokens == _reference_generate(model, params, onp, 12)
