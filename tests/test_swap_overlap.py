"""Swap/compute overlap: the exposed-tail clock vs the serial charge,
overlap-on/off trace equivalence, copy-stream fence correctness (including
a plan touching a block whose transfer is still in flight), host-tier-aware
routing/stealing, and swap-term recalibration from staging wall times."""
import time

import jax
import numpy as np
import pytest

from repro.core import (ECHO, SLO, EchoEngine, Request, TaskType, TimeModel)
from repro.core.block_io import KV_BYTES_PER_TOKEN_8B
from repro.core.block_manager import HostBlock, chain_hash
from repro.core.calibration import OnlineCalibrator
from repro.core.engine import _SwapStager
from repro.core.estimator import MemoryPredictor
from repro.core.scheduler import Plan
from repro.core.simulator import clone_requests
from repro.data import make_offline_corpus


def _req(tokens, task=TaskType.OFFLINE, max_new=4):
    r = Request(prompt=tuple(tokens), max_new_tokens=max_new, task_type=task)
    r.admit()
    return r


# ----------------------------------------------------------- TimeModel math
def test_overlapped_iteration_time_max_plus_launch():
    tm = TimeModel.a100()
    assert tm.swap_overlap and tm.swap_launch > 0
    compute, transfer = 0.01, 0.004
    assert tm.overlapped_iteration_time(compute, 0.0) == compute
    assert tm.overlapped_iteration_time(compute, transfer) == pytest.approx(
        max(compute, transfer) + tm.swap_launch)
    # transfer-bound iteration: the tail beyond compute is exposed
    assert tm.overlapped_iteration_time(0.001, transfer) == pytest.approx(
        transfer + tm.swap_launch)
    assert tm.exposed_swap_time(compute, transfer) == pytest.approx(
        tm.overlapped_iteration_time(compute, transfer) - compute)
    # serial fallback: exactly the pre-overlap charge
    serial = TimeModel.a100(swap_overlap=False)
    assert serial.overlapped_iteration_time(compute, transfer) == \
        pytest.approx(compute + transfer)


def test_perturbed_model_passes_overlap_terms_through():
    base = TimeModel.a100()
    pm = base.perturbed(scale=2.0)
    assert pm.swap_overlap is base.swap_overlap
    assert pm.swap_launch == pytest.approx(2.0 * base.swap_launch)
    compute, transfer = 0.002, 0.008
    assert pm.overlapped_iteration_time(compute, transfer) == pytest.approx(
        max(compute, transfer) + 2.0 * base.swap_launch)
    serial = TimeModel.a100(swap_overlap=False).perturbed(scale=2.0)
    assert serial.overlapped_iteration_time(compute, transfer) == \
        pytest.approx(compute + transfer)


def test_fit_swap_overlap_recovers_launch_overhead():
    tm = TimeModel.a100(swap_launch=0.0)
    true_launch = 3e-4
    samples = []
    for compute, n in ((0.01, 256), (0.002, 1024), (0.03, 64), (0.005, 512)):
        total = max(compute, tm.swap_time(n)) + true_launch
        samples.append((compute, n, total))
    tm.fit_swap_overlap(samples)
    assert tm.swap_launch == pytest.approx(true_launch, rel=1e-6)
    # robust to an outlier iteration where a fence exposed extra time
    tm.fit_swap_overlap(samples + [(0.01, 256, 1.0)])
    assert tm.swap_launch == pytest.approx(true_launch, rel=1e-6)


def test_host_reserve_extends_for_inflight_staging():
    mp = MemoryPredictor()
    mp.observe(0.0, 160.0)             # predicted online demand: 10 blocks
    base = mp.host_reserve_blocks(16)
    assert mp.host_reserve_blocks(16, inflight_blocks=3) == base + 3
    # the cap still bounds the total reserve
    assert mp.host_reserve_blocks(16, cap_blocks=8, inflight_blocks=100) == 4


# ------------------------------------------------------- scheduler pricing
def test_hidden_transfer_rescues_slow_link_only_without_displacement():
    """A transfer that loses the raw seconds race (slow link) is still
    worthwhile once the batch is busy enough to hide it — but only when
    free blocks cover the restore (an eviction-funded restore churns the
    tier and stays priced at link rate)."""
    # ~4e-4 s per token-equivalent: clearly loses serially to the prefill
    # floor, but hides under a busy batch
    tm = TimeModel.a100(swap_byte=4e-4 / KV_BYTES_PER_TOKEN_8B,
                        swap_floor=0.0)
    eng = EchoEngine(None, None, ECHO, num_blocks=64, block_size=16,
                     time_model=tm, host_kv_blocks=64)
    sched = eng.scheduler
    n = 16
    assert tm.swap_time(sched._restore_bytes(n)) > \
        tm.prefill_time([(0, n)]), "scenario needs a serially-losing transfer"
    busy = _req(range(2048))
    plan = Plan(prefills=[(busy, 1024)])
    assert sched._swap_in_worthwhile(0, n, plan), \
        "hidden under a busy batch the transfer should win"
    assert not sched._swap_in_worthwhile(0, n, None), \
        "without a plan the serial price decides"
    # drain the free list: the discount must vanish under displacement
    filler = _req(range(3000, 3000 + 64 * 16), max_new=0)
    assert eng.bm.allocate(filler, 64 * 16, filler.full_tokens, 0.0) is not None
    assert eng.bm.free_blocks == 0
    assert not sched._swap_in_worthwhile(0, n, plan), \
        "an eviction-funded restore must not ride the overlap discount"
    # overlap off: always the serial comparison
    tm_serial = TimeModel.a100(swap_byte=4e-4 / KV_BYTES_PER_TOKEN_8B,
                               swap_floor=0.0, swap_overlap=False)
    eng2 = EchoEngine(None, None, ECHO, num_blocks=64, block_size=16,
                      time_model=tm_serial, host_kv_blocks=64)
    assert not eng2.scheduler._swap_in_worthwhile(0, n, plan)


# ------------------------------------------------- trace equivalence (§sim)
def _offline_pressure_engine(swap_overlap: bool):
    tm = TimeModel.a100(swap_overlap=swap_overlap)
    return EchoEngine(None, None, ECHO, num_blocks=64, block_size=16,
                      chunk_size=64, time_model=tm, host_kv_blocks=160)


def test_overlap_same_tokens_faster_clock():
    """Overlap-on vs overlap-off on an offline-only workload under memory
    pressure: the schedules coincide (no SLO budget in play, and the
    serially-winning transfers are taken either way), so every request
    emits the SAME tokens — only the clock differs, and it differs by
    exactly the hidden transfer time."""
    offline = make_offline_corpus(5, 24, doc_len=240, question_len=24,
                                  max_new=8, seed=7)
    runs = {}
    for overlap in (False, True):
        eng = _offline_pressure_engine(overlap)
        for r in clone_requests(offline, preserve_rid=True):
            eng.submit(r)
        stats = eng.run(max_iters=40_000)
        assert eng.bm.metrics.swapped_in_tokens > 0, \
            "scenario must exercise the swap path"
        runs[overlap] = (eng, stats,
                         {r.rid: list(r.output_tokens)
                          for r in stats.finished})
    eng_s, stats_s, toks_s = runs[False]
    eng_o, stats_o, toks_o = runs[True]
    assert toks_s == toks_o, "overlap must not change what is computed"
    assert stats_o.swap_transfer_time == pytest.approx(
        stats_s.swap_transfer_time), "same transfers either way"
    assert stats_s.swap_exposed_time == pytest.approx(
        stats_s.swap_transfer_time), "serial: everything exposed"
    assert stats_o.swap_exposed_time < stats_o.swap_transfer_time
    assert stats_o.swap_hidden_frac() > 0.5
    assert eng_o.now < eng_s.now, \
        "hiding transfers must shorten the virtual makespan"


# --------------------------------------------------- copy-stream fences
class _SlowMockRunner:
    """Runner stub whose D2H materialization is slow — enough to catch a
    fence that doesn't actually wait."""

    def __init__(self, delay=0.02):
        self.delay = delay
        self.pages = {}                 # bid -> staged payload
        self.calls = []

    def snapshot_block(self, bid):
        self.calls.append(("snap", bid))
        return ("snapshot", bid)

    def materialize(self, snap):
        time.sleep(self.delay)
        self.calls.append(("materialize", snap[1]))
        return ("payload", snap[1])

    def stage_payload(self, payload):
        self.calls.append(("stage", payload))
        return ("staged", payload)

    def write_block(self, bid, staged):
        self.calls.append(("write", bid))
        self.pages[bid] = staged


def test_fence_completes_out_staging_before_reuse():
    runner = _SlowMockRunner()
    stager = _SwapStager(runner)
    hb = HostBlock(hash=1, n_tokens=16, task_type=TaskType.OFFLINE)
    stager.launch([("out", 5, hb)])
    assert stager.inflight_blocks() == 1
    stager.fence([5])                  # the plan is about to write bid 5
    assert hb.payload == ("payload", 5), \
        "fence must not return before the payload landed"
    assert stager.inflight_blocks() == 0
    assert stager.exposed_wall > 0.0 and stager.staged_wall > 0.0


def test_in_event_waits_for_its_producing_out():
    """A block swapped out and back in within the same drain shares one
    HostBlock: the single-worker FIFO must run the out's materialization
    before the in's upload, or the in would stage a None payload."""
    runner = _SlowMockRunner()
    stager = _SwapStager(runner)
    hb = HostBlock(hash=2, n_tokens=16, task_type=TaskType.OFFLINE)
    stager.launch([("out", 3, hb), ("in", 7, hb)])
    stager.fence([7])                  # plan reads bid 7 this iteration
    assert ("write", 7) in runner.calls
    order = [c[0] for c in runner.calls]
    assert order.index("materialize") < order.index("stage"), \
        "FIFO must stage the out before the dependent in"
    assert runner.pages[7] == ("staged", ("payload", 3))


def test_launch_fences_repurposed_block():
    """Plan touches a block still in flight (satellite): when a bid is
    re-journaled while its previous transfer is pending, launch itself
    must fence — per-page transfer order is the correctness contract."""
    runner = _SlowMockRunner()
    stager = _SwapStager(runner)
    hb1 = HostBlock(hash=3, n_tokens=16, task_type=TaskType.OFFLINE)
    hb2 = HostBlock(hash=4, n_tokens=16, task_type=TaskType.OFFLINE,
                    payload=("payload", "preloaded"))
    stager.launch([("out", 9, hb1)])
    stager.launch([("in", 9, hb2)])    # same bid re-purposed next drain
    assert hb1.payload is not None, \
        "re-purposing a bid must complete its in-flight transfer first"
    stager.fence([9])
    assert runner.pages[9] == ("staged", ("payload", "preloaded"))
    stager.flush()
    assert stager.inflight_blocks() == 0


def test_stager_roundtrip_matches_sync_path(tiny_cfg):
    """Split-phase staging (snapshot -> worker materialize -> worker upload
    -> owner-thread scatter) must be bit-exact with the synchronous
    read_block/write_block path."""
    from repro.models import Model
    from repro.models.paged import PagedRunner

    m = Model(tiny_cfg)
    params = m.init(jax.random.PRNGKey(0))
    runner = PagedRunner(m, params, num_pages=8, page_size=8,
                         max_pages_per_seq=8, chunk_size=16)
    runner.prefill_chunk(list(range(16)), 0, [1, 2])
    want = runner.read_block(1)

    got = runner.materialize(runner.snapshot_block(1))
    flat_w = jax.tree_util.tree_leaves(want)
    flat_g = jax.tree_util.tree_leaves(got)
    for a, b in zip(flat_w, flat_g):
        assert np.array_equal(a, b)

    zeros = jax.tree_util.tree_map(np.zeros_like, want)
    runner.write_block(1, zeros)
    staged = runner.stage_payload(got)  # worker-side upload
    runner.write_block(1, staged)       # owner-side scatter
    back = runner.read_block(1)
    for a, b in zip(jax.tree_util.tree_leaves(want),
                    jax.tree_util.tree_leaves(back)):
        assert np.array_equal(a, b), "async staging must stay bit-exact"


def test_wall_clock_engine_with_overlap_generates_reference_tokens(tiny_cfg):
    """End-to-end on the wall path: preemption, eviction-to-host, async
    staging, and swap-restore with the double buffer active — generation
    must still match the dense greedy reference (fences land every payload
    before its page is read)."""
    from test_engine import _reference_generate
    from repro.models import Model

    model = Model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    vocab = model.cfg.vocab_size
    offp = tuple(int(x) for x in rng.integers(0, vocab, 56))
    onp = tuple(int(x) for x in rng.integers(0, vocab, 88))
    off = Request(prompt=offp, max_new_tokens=6, task_type=TaskType.OFFLINE)
    eng = EchoEngine(model, params, ECHO, num_blocks=16, block_size=8,
                     chunk_size=16, max_pages_per_seq=16,
                     host_kv_blocks=32, clock="wall")
    assert eng._stager is not None, "overlap stager must engage on the " \
        "wall path with a paged runner and a host tier"
    eng.submit(off)
    for _ in range(3):
        eng.step()
    on = Request(prompt=onp, max_new_tokens=12, task_type=TaskType.ONLINE,
                 arrival_time=eng.now, slo=SLO(10, 10))
    eng.submit(on)
    eng.run(max_iters=1000)
    assert off.done and on.done
    assert eng.bm.metrics.swapped_in_tokens > 0, \
        "scenario must exercise the async restore path"
    assert off.output_tokens == _reference_generate(model, params, offp, 6)
    assert on.output_tokens == _reference_generate(model, params, onp, 12)
    assert eng.stats.swap_transfer_time > 0.0


# --------------------------------------------------- host-aware routing
def _park_doc_on_host(eng, doc_tokens):
    """Insert ``doc_tokens``'s full-block chain into the engine's host tier
    (as if an online burst had flushed it off device)."""
    bs = eng.bm.block_size
    prev = 0
    for bi in range(len(doc_tokens) // bs):
        prev = chain_hash(prev, tuple(doc_tokens[bi * bs:(bi + 1) * bs]))
        assert eng.bm.host.admit(HostBlock(hash=prev, n_tokens=bs,
                                           task_type=TaskType.OFFLINE))


def test_router_steers_offline_toward_parked_host_kv():
    from repro.cluster import Replica, Router

    reps = [Replica.simulated(i, ECHO, num_blocks=64, host_kv_blocks=64)
            for i in range(2)]
    doc = tuple(range(900, 900 + 64))
    _park_doc_on_host(reps[1].engine, doc)
    # replica 0 is otherwise preferable (strictly smaller backlog)
    reps[0].engine.submit(Request(prompt=tuple(range(5)), max_new_tokens=1,
                                  task_type=TaskType.OFFLINE))
    router = Router(reps, policy="affinity")
    req = Request(prompt=doc + tuple(range(40, 48)), max_new_tokens=4,
                  task_type=TaskType.OFFLINE)
    assert reps[1].host_prefix_blocks(req) == 4
    assert router.dispatch(req) is reps[1], \
        "parked host KV must attract the document's group"


def test_device_cached_prefix_outranks_host_parked_copy():
    """Regression (review): the tiers must score symmetrically, 1 per
    block — a replica holding the document in DEVICE cache (free reuse)
    must never lose the dispatch to one that would restore it over PCIe."""
    from repro.cluster import Replica, Router

    reps = [Replica.simulated(i, ECHO, num_blocks=64, host_kv_blocks=64)
            for i in range(2)]
    doc = tuple(range(800, 800 + 64))
    _park_doc_on_host(reps[1].engine, doc)           # 4 blocks, host tier
    bm0 = reps[0].engine.bm
    filler = _req(doc)
    assert bm0.allocate(filler, len(doc), filler.full_tokens, 0.0) is not None
    filler.computed_tokens = len(doc)
    bm0.commit(filler, filler.full_tokens, 0.0)      # 4 blocks, device cache
    bm0.free_request(filler, 1.0, finished=True)
    router = Router(reps, policy="affinity")
    req = Request(prompt=doc + tuple(range(30, 38)), max_new_tokens=4,
                  task_type=TaskType.OFFLINE)
    assert router.dispatch(req) is reps[0], \
        "a device-cached prefix must outrank the same prefix parked on host"


def test_rebalance_steals_toward_parked_host_kv():
    from repro.cluster import Replica, Router

    reps = [Replica.simulated(i, ECHO, num_blocks=64, host_kv_blocks=64)
            for i in range(3)]
    doc = tuple(range(700, 700 + 64))
    _park_doc_on_host(reps[2].engine, doc)
    # replica 0: online-overloaded with a pooled offline backlog
    for i in range(6):
        reps[0].engine.submit(Request(
            prompt=tuple(range(i * 10, i * 10 + 8)), max_new_tokens=2,
            task_type=TaskType.ONLINE, slo=SLO(1.0, 0.1)))
    stolen_req = Request(prompt=doc + tuple(range(20, 28)), max_new_tokens=4,
                         task_type=TaskType.OFFLINE)
    reps[0].engine.submit(stolen_req)
    # replica 1 is calmer by every load signal — but replica 2 parks the KV
    router = Router(reps, policy="affinity", steal_queue_depth=4)
    moved = router.rebalance()
    assert moved >= 1
    assert stolen_req in reps[2].engine.pending, \
        "stealing must move work toward the replica holding its KV"
    assert router.stats.steal_affinity_hits >= 1


# --------------------------------------------------- swap-term calibration
def test_calibrator_refits_swap_terms_from_staging_times():
    tm = TimeModel.a100()
    true_byte, true_floor = tm.swap_byte * 2.5, tm.swap_floor
    cal = OnlineCalibrator(tm, cooldown=8, min_samples=9)
    rng = np.random.default_rng(0)
    for _ in range(40):
        n = int(rng.integers(16, 512)) * KV_BYTES_PER_TOKEN_8B
        cal.observe_swap(n, true_byte * n + true_floor)
    assert cal.swap_refits >= 1, "sustained 2.5x swap drift must refit"
    assert tm.swap_byte == pytest.approx(true_byte, rel=0.05)
    assert cal.n_swap_observed == 40
    # converged: post-refit error stays under the drift threshold
    n = 256 * KV_BYTES_PER_TOKEN_8B
    rel = abs(tm.swap_time(n) - (true_byte * n + true_floor)) \
        / (true_byte * n + true_floor)
    assert rel < cal.drift_threshold


def test_calibrator_refits_launch_overhead_from_overlap_samples():
    tm = TimeModel.a100(swap_launch=1e-5)
    true = TimeModel.a100(swap_byte=TimeModel.a100().swap_byte * 3,
                          swap_launch=5e-4)       # the real link + launch
    cal = OnlineCalibrator(tm, cooldown=8, min_samples=9)
    rng = np.random.default_rng(1)
    for _ in range(40):
        n = int(rng.integers(64, 512)) * KV_BYTES_PER_TOKEN_8B
        compute = float(rng.uniform(0.001, 0.02))
        transfer = true.swap_time(n)
        cal.observe_overlap(compute, n,
                            max(compute, transfer) + true.swap_launch)
        cal.observe_swap(n, transfer)
    assert cal.swap_refits >= 1
    # fit order inside refit_swap matters: the PCIe terms converge first,
    # so the overlap residual isolates the launch overhead
    assert tm.swap_byte == pytest.approx(true.swap_byte, rel=0.05)
    assert tm.swap_launch == pytest.approx(true.swap_launch, rel=0.25)


def test_engine_feeds_swap_observations_to_calibrator():
    """Virtual-clock engine with a drifted ground-truth link: the swap
    terms must track the clock without touching the compute coefficients'
    cleanliness (transfer seconds never enter Eq.6-8 samples)."""
    tm = TimeModel.a100()
    clock = TimeModel.a100(swap_byte=tm.swap_byte * 3)
    cal = OnlineCalibrator(tm, cooldown=3, min_samples=6)
    eng = EchoEngine(None, None, ECHO, num_blocks=64, block_size=16,
                     chunk_size=64, time_model=tm, clock_model=clock,
                     calibrator=cal, host_kv_blocks=160)
    offline = make_offline_corpus(8, 32, doc_len=240, question_len=24,
                                  max_new=8, seed=7)
    for r in offline:
        eng.submit(r)
    eng.run(max_iters=60_000)
    assert cal.n_swap_observed > 0, "swap traffic must reach the calibrator"
    assert cal.swap_refits >= 1, "3x link drift must trigger a swap refit"
    assert tm.swap_byte == pytest.approx(clock.swap_byte, rel=0.2)


# --------------------------------------------------- serving live metrics
def test_live_metrics_track_overlap_split():
    from repro.serving import EchoService

    eng = _offline_pressure_engine(True)
    service = EchoService(eng)
    offline = make_offline_corpus(5, 24, doc_len=240, question_len=24,
                                  max_new=8, seed=7)
    stats = service.drive(offline, max_iters=40_000)
    assert service.live.swap_transfer_time == pytest.approx(
        stats.swap_transfer_time)
    assert service.live.swap_exposed_time == pytest.approx(
        stats.swap_exposed_time)
    assert service.live.swap_hidden_frac() == pytest.approx(
        stats.swap_hidden_frac())
    assert service.live.swap_hidden_frac() > 0.5
