"""Cluster subsystem: router placement, work stealing, fleet determinism,
and planner monotonicity."""
import pytest

from repro.cluster import (ClusterSimulator, FleetPlanner, Replica, Router,
                           first_block_hash)
from repro.core import (ECHO, SLO, Request, RequestState, TaskType,
                        TimeModel)
from repro.core.simulator import clone_requests
from repro.data import (TenantSpec, default_tenants,
                        make_multi_tenant_workload)

def _tm():
    return TimeModel.a100()


def _replicas(n, *, num_blocks=96, seed=0):
    tm = _tm()
    return [Replica.simulated(i, ECHO, num_blocks=num_blocks, time_model=tm,
                              seed=seed + i) for i in range(n)]


def _online(plen=64, t=0.0, max_new=8):
    return Request(prompt=tuple(range(plen)), max_new_tokens=max_new,
                   task_type=TaskType.ONLINE, arrival_time=t,
                   slo=SLO(1.0, 0.1))


def _offline(prompt, t=0.0, max_new=4):
    return Request(prompt=tuple(prompt), max_new_tokens=max_new,
                   task_type=TaskType.OFFLINE, arrival_time=t)


def _workload(duration=12.0, seed=0, n_docs=4, questions=16):
    tenants = (TenantSpec("a", online_rate=1.0, n_docs=n_docs,
                          questions_per_doc=questions),
               TenantSpec("b", online_rate=0.5, slo=SLO(1.5, 0.15),
                          n_docs=n_docs, questions_per_doc=questions))
    return make_multi_tenant_workload(tenants, duration, seed=seed)


# ---------------------------------------------------------------- placement
def test_online_goes_to_least_loaded_replica():
    reps = _replicas(2)
    router = Router(reps, policy="affinity")
    # pile online work onto replica 0's queue
    for i in range(6):
        reps[0].engine.scheduler.online_queue.append(_online(128, t=0.0))
    placed = router.dispatch(_online(64, t=0.0))
    assert placed is reps[1]


def test_online_wins_placement_over_offline_backlog():
    """Online placement ignores offline backlog: the replica drowning in
    offline pool work but idle online-wise still gets the online request."""
    reps = _replicas(2)
    router = Router(reps, policy="affinity")
    doc = tuple(range(1000, 1256))
    for i in range(20):     # replica 1: heavy *pooled* offline backlog
        reps[1].engine.scheduler.submit(_offline(doc + (i, i, i, i)))
    # replica 0: online queue -> predicted latency higher there
    for i in range(4):
        reps[0].engine.scheduler.online_queue.append(_online(128))
    placed = router.dispatch(_online(64))
    assert placed is reps[1]


def test_affinity_routes_group_to_home_replica():
    reps = _replicas(2)
    router = Router(reps, policy="affinity")
    bs = reps[0].engine.bm.block_size
    doc_a = tuple(range(300, 300 + 4 * bs))
    doc_b = tuple(range(600, 600 + 4 * bs))
    first = router.dispatch(_offline(doc_a + (1, 2)))
    # same document group follows its home replica
    for i in range(3):
        assert router.dispatch(_offline(doc_a + (10 + i,))) is first
    # a fresh group opens on the *other* (least-backlogged) replica
    other = router.dispatch(_offline(doc_b + (1, 2)))
    assert other is not first
    assert router.stats.affinity_hits == 3
    fh = first_block_hash(_offline(doc_a), bs)
    assert first.affinity(fh) > 0
    # once the engine pulls arrivals into its pool, the group shows up in
    # the exported radix summary
    first.engine.now = 1.0
    first.engine._pull_arrivals()
    assert first.prefix_summary()[fh] == 4


def test_work_stealing_on_online_spike():
    reps = _replicas(2)
    router = Router(reps, policy="affinity", steal_queue_depth=4,
                    steal_batch=8)
    doc = tuple(range(2000, 2128))
    for i in range(10):
        reps[0].engine.scheduler.submit(_offline(doc + (i,)))
    assert reps[0].offline_backlog() == 10
    router.rebalance()
    assert router.stats.steals == 0          # no spike yet: nothing moves
    for i in range(5):                        # online load spikes on 0
        reps[0].engine.scheduler.online_queue.append(_online(128))
    moved = router.rebalance()
    assert moved > 0
    assert reps[1].offline_backlog() == moved
    assert reps[0].stolen_out == moved and reps[1].stolen_in == moved


def test_rebalance_survives_donor_queue_emptying_mid_scan():
    """Two donors spike at once. Donor 0's stealable queue holds fewer
    requests than ``steal_batch`` (it empties mid-steal); donor 1 reports
    ``offline_backlog() > 0`` but its only offline request is RUNNING, so
    ``steal_offline`` yields nothing — rebalance must skip it without
    crashing or double-counting a steal event."""
    reps = _replicas(3)
    router = Router(reps, policy="affinity", steal_queue_depth=4,
                    steal_batch=8)
    bs = reps[0].engine.bm.block_size
    doc = tuple(range(700, 700 + 2 * bs))
    for _ in range(4):                       # donor 0: online spike...
        reps[0].engine.scheduler.online_queue.append(_online(128))
    for i in range(2):                       # ...but only 2 stealable reqs
        reps[0].engine.submit(_offline(doc + (i,)))
    for _ in range(4):                       # donor 1: spike + backlog that
        reps[1].engine.scheduler.online_queue.append(_online(128))
    stuck = _offline(tuple(range(900, 900 + bs)))
    stuck.state = RequestState.RUNNING       # ...is entirely in-flight
    reps[1].engine.scheduler.running.append(stuck)
    assert reps[1].offline_backlog() == 1

    moved = router.rebalance()
    assert moved == 2                        # donor 0 drained dry, no error
    assert reps[0].offline_backlog() == 0
    assert reps[2].offline_backlog() == 2    # calm replica took the work
    assert reps[1].offline_backlog() == 1    # running request never moves
    assert router.stats.steals == 1          # donor 1 contributed no event
    assert router.stats.stolen_requests == 2
    assert router.rebalance() == 0           # second scan finds nothing


def test_dispatch_targets_up_replica_when_fleet_idle_but_one_draining():
    """Every replica reports ``has_work() == False`` but one is DRAINING:
    dispatch must route both task types to the UP replica, and raise once
    no routable replica remains."""
    reps = _replicas(2)
    reps[0].begin_drain()
    assert not any(r.has_work() for r in reps)
    router = Router(reps, policy="affinity")
    assert router.routable() == [reps[1]]
    assert router.dispatch(_online(64)) is reps[1]
    assert router.dispatch(_offline(tuple(range(400, 432)))) is reps[1]
    reps[1].begin_drain()
    with pytest.raises(RuntimeError, match="no routable replica"):
        router.dispatch(_online(64))


# ---------------------------------------------------------------- simulator
def _fingerprint(stats):
    m = stats.merged()
    iters = [(round(r.t, 9), r.n_prefill, r.n_decode, r.offline_tokens,
              r.online_tokens) for r in m.iterations]
    finished = sorted((r.arrival_time, r.prompt_len, r.max_new_tokens,
                       round(r.finish_time, 9)) for r in m.finished)
    return iters, finished


def test_cluster_simulator_deterministic_on_virtual_clock():
    online, offline = _workload()

    def run_once():
        sim = ClusterSimulator(3, ECHO, router_policy="affinity",
                               num_blocks=96, time_model=_tm(), seed=0)
        sim.submit_all(clone_requests(online) + clone_requests(offline))
        return _fingerprint(sim.run(until_time=60.0))

    assert run_once() == run_once()


def test_cluster_completes_all_work_and_aggregates():
    online, offline = _workload()
    sim = ClusterSimulator(2, ECHO, router_policy="affinity", num_blocks=96,
                           time_model=_tm(), seed=0)
    sim.submit_all(clone_requests(online) + clone_requests(offline))
    stats = sim.run(until_time=120.0)
    on, off = stats.finished_counts()
    assert on == len(online) and off == len(offline)
    assert stats.offline_throughput() > 0
    # fleet aggregation really spans replicas
    assert all(st.iterations for st in stats.replicas)
    assert sum(stats.per_replica_offline_tokens()) == sum(
        r.prompt_len + r.n_output
        for r in stats.merged().finished if not r.is_online)


def test_affinity_beats_random_on_shared_prefix_corpus():
    online, offline = _workload(n_docs=6, questions=20)

    def tput(policy):
        sim = ClusterSimulator(2, ECHO, router_policy=policy, num_blocks=96,
                               time_model=_tm(), seed=0)
        sim.submit_all(clone_requests(online) + clone_requests(offline))
        stats = sim.run(until_time=120.0)
        return (stats.offline_throughput(), stats.slo_attainment("ttft"))

    aff_tput, aff_slo = tput("affinity")
    rnd_tput, rnd_slo = tput("random")
    assert aff_tput > rnd_tput
    assert aff_slo >= rnd_slo


# ---------------------------------------------------------------- planner
def test_fleet_planner_slo_monotone_in_replicas():
    """More replicas only dilute online load: attainment non-decreasing."""
    import dataclasses
    tenants = tuple(dataclasses.replace(t, online_rate=t.online_rate * 12)
                    for t in default_tenants(2))
    online, _ = make_multi_tenant_workload(tenants, 8.0, seed=3)
    planner = FleetPlanner(_tm())
    curve = planner.attainment_curve(online, candidate_replicas=(1, 2, 4),
                                     num_blocks=96, duration=8.0)
    atts = [a for _, a in curve]
    assert atts == sorted(atts)
    assert atts[-1] > atts[0]       # the sweep actually spans load regimes


def test_fleet_planner_finds_min_feasible_fleet():
    import dataclasses
    tenants = tuple(dataclasses.replace(t, online_rate=t.online_rate * 12)
                    for t in default_tenants(2))
    online, offline = make_multi_tenant_workload(tenants, 8.0, seed=3)
    planner = FleetPlanner(_tm())
    rep = planner.plan(online, offline, candidate_replicas=(1, 2, 4),
                       candidate_blocks=(96,), slo_target=0.9, duration=8.0)
    assert rep.min_replicas is not None
    assert rep.offline_throughput is not None and rep.offline_throughput > 0
    chosen = [a for n, nb, a in rep.slo_by_config
              if n == rep.min_replicas and nb == rep.blocks_per_replica]
    assert chosen and chosen[0] >= 0.9
    # every smaller probed fleet missed the target
    for n, nb, att in rep.slo_by_config:
        if n < rep.min_replicas:
            assert att < 0.9
