"""Deliverable (f): per-arch REDUCED smoke — one forward/train step on CPU,
asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.training import adamw_init, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    b, s = 2, 32
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    mm = jnp.ones((b, 8, cfg.mm_embed_dim)) if cfg.multimodal else None

    logits = m.forward_train(params, toks, mm_embeds=mm)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not jnp.isnan(logits).any()

    batch = {"tokens": toks, "labels": toks}
    if mm is not None:
        batch["mm_embeds"] = mm
    step = jax.jit(make_train_step(m, total_steps=10))
    params2, opt2, metrics = step(params, adamw_init(params), batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not jnp.allclose(l0, l1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    b, s = 2, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    last, cache = m.prefill(params, toks)
    assert last.shape == (b, cfg.vocab_size)
    assert not jnp.isnan(last).any()
    cache = m.pad_cache(cache, s, 32)
    lg, cache = m.decode_step(params, jnp.argmax(last, -1).astype(jnp.int32),
                              cache, jnp.full((b,), s, jnp.int32))
    assert lg.shape == (b, cfg.vocab_size)
    assert not jnp.isnan(lg).any()
