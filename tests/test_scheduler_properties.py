"""Hypothesis property tests on scheduler/system invariants."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ECHO, SLO, EchoEngine, Request, TaskType, TimeModel


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(),            # online?
                          st.integers(4, 60),       # prompt len
                          st.integers(1, 6),        # max_new
                          st.floats(0, 2.0)),       # arrival
                min_size=1, max_size=16),
       st.integers(16, 64),                         # num_blocks
       st.sampled_from([8, 16]))                    # block size
def test_engine_invariants(spec, num_blocks, bs):
    """Across arbitrary workloads: memory is never oversubscribed, decodes
    only run after prefill completes, online queue drains FCFS, and every
    token is attributed to exactly one request."""
    import numpy as np
    rng = np.random.default_rng(0)
    tm = TimeModel(alpha=1e-7, beta=1e-4, c=1e-3, gamma=1e-5, delta=1e-5,
                   d0=1e-3, lam=0.9)
    eng = EchoEngine(None, None, ECHO, num_blocks=num_blocks, block_size=bs,
                     chunk_size=2 * bs, time_model=tm)
    reqs = []
    for online, plen, mn, t in spec:
        prompt = tuple(int(x) for x in rng.integers(0, 64, plen))
        reqs.append(Request(prompt=prompt, max_new_tokens=mn,
                            task_type=TaskType.ONLINE if online
                            else TaskType.OFFLINE,
                            arrival_time=float(t),
                            slo=SLO(5.0, 1.0) if online else None))
    for r in reqs:
        eng.submit(r)
    for _ in range(400):
        before_queue = list(eng.scheduler.online_queue)
        rec = eng.step()
        # invariant: block accounting is conserved and never oversubscribed
        used = sum(1 for b in eng.bm.blocks if b.ref > 0)
        assert used + eng.bm.free_blocks + eng.bm.evictable_count() \
            == eng.bm.num_blocks
        # invariant: decodes have completed prefill
        for req in eng.scheduler.running:
            if req.state.value == "running" and req.prefill_done:
                assert req.computed_tokens >= req.prefill_target_len
        # invariant: FCFS — queue only ever pops from the left
        after_queue = list(eng.scheduler.online_queue)
        if after_queue and before_queue:
            tail = [r for r in before_queue if r in after_queue]
            assert tail == after_queue[-len(tail):] if tail else True
        if not eng.pending and not eng.scheduler.running \
                and not eng.scheduler.online_queue and len(eng.pool) == 0:
            break
    done = [r for r in eng.stats.finished]
    for r in done:
        assert len(r.output_tokens) == r.max_new_tokens
    # no request counted twice
    assert len({r.rid for r in done}) == len(done)
