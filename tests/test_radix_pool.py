"""Offline pool: rc accounting + candidate structure."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.block_manager import chain_hash
from repro.core.radix_pool import OfflinePool
from repro.core.request import Request, TaskType


def _off(prompt, t=0.0):
    return Request(prompt=tuple(prompt), max_new_tokens=4,
                   task_type=TaskType.OFFLINE, arrival_time=t)


def test_rc_counts_sharers():
    pool = OfflinePool(block_size=4)
    doc = (1, 2, 3, 4, 5, 6, 7, 8)
    r1 = _off(doc + (10, 11, 12, 13))
    r2 = _off(doc + (20, 21, 22, 23))
    r3 = _off((9, 9, 9, 9, 1, 2, 3, 4))
    for r in (r1, r2, r3):
        pool.add(r)
    h1 = chain_hash(0, doc[:4])
    h2 = chain_hash(h1, doc[4:8])
    assert pool.rc(h1) == 2
    assert pool.rc(h2) == 2
    pool.remove(r1)
    assert pool.rc(h1) == 1
    pool.remove(r2)
    assert pool.rc(h1) == 0


def test_candidates_one_per_group():
    pool = OfflinePool(block_size=4)
    doc_a, doc_b = (1,) * 4, (2,) * 4
    reqs = [_off(doc_a + (i,) * 4, t=i) for i in range(3)]
    reqs += [_off(doc_b + (i,) * 4, t=10 + i) for i in range(3)]
    for r in reqs:
        pool.add(r)
    cands = list(pool.candidates())
    groups = {r.prompt[:4] for r in cands}
    assert groups == {doc_a, doc_b}


def test_candidates_fcfs_within_group_after_readd():
    """Group heads follow (arrival_time, rid) like fcfs_head — re-adding a
    preempted request at the tail of the OrderedDict must not demote it."""
    pool = OfflinePool(block_size=4)
    doc = (1,) * 4
    early = _off(doc + (10,) * 4, t=0.0)
    late = _off(doc + (20,) * 4, t=5.0)
    pool.add(early)
    pool.remove(early)              # admitted
    pool.add(late)
    pool.add(early)                 # preempted: back at insertion tail
    cands = list(pool.candidates())
    assert cands == [early]         # one head per group, earliest arrival

    # across groups, heads are yielded in FCFS order too
    other = _off((2,) * 8, t=1.0)
    pool.add(other)
    cands = list(pool.candidates())
    assert cands == [early, other]


def test_fcfs_head_earliest():
    pool = OfflinePool(block_size=4)
    r_late = _off((1,) * 8, t=5.0)
    r_early = _off((2,) * 8, t=1.0)
    pool.add(r_late)
    pool.add(r_early)
    assert pool.fcfs_head() is r_early


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 40)),
                min_size=1, max_size=20))
def test_pool_add_remove_roundtrip(spec):
    """rc is exactly the number of pooled requests passing each chunk."""
    pool = OfflinePool(block_size=4)
    reqs = []
    for doc, salt in spec:
        prompt = tuple([doc] * 8 + [salt] * 4)
        r = _off(prompt)
        pool.add(r)
        reqs.append(r)
    # ground-truth rc for each doc's first chunk
    from collections import Counter
    first = Counter(r.prompt[:4] for r in reqs)
    for chunk, n in first.items():
        assert pool.rc(chain_hash(0, chunk)) == n
    for r in reqs:
        pool.remove(r)
    assert len(pool) == 0
    assert pool.hash_count == {}
