"""Observability layer: histogram/percentile math, Prometheus and JSON
exposition round-trips, EventBus fault isolation, lifecycle tracing with
zero-cost parity against an uninstrumented run, estimator-drift probes,
and live-vs-post-hoc metric parity on engine and cluster backends."""
import json
import logging

import numpy as np
import pytest

from repro.cluster import ClusterSimulator
from repro.core import ECHO, ECHO_C, SLO, EchoEngine, TimeModel
from repro.core.calibration import OnlineCalibrator
from repro.core.simulator import clone_requests
from repro.data import make_offline_corpus, make_online_requests
from repro.obs import (LATENCY_BUCKETS, Histogram, MetricsRegistry, Tracer,
                       instrument, instrument_engine, parse_prometheus)
from repro.obs.check import check_prometheus, check_trace
from repro.serving import EchoService


def _tm(**kw):
    return TimeModel.a100(**kw)


def _engine(policy=ECHO_C, num_blocks=48, host_kv_blocks=64, **kw):
    """Small device cache + host tier: online bursts evict the offline
    working set, so a short drive exercises preempt AND swap paths."""
    return EchoEngine(None, None, policy, num_blocks=num_blocks,
                     block_size=16, chunk_size=32, time_model=_tm(),
                     host_kv_blocks=host_kv_blocks, **kw)


def _pressure_workload(seed=0, duration=4.0, rate=6.0):
    rng = np.random.default_rng(seed)
    arrivals = list(np.cumsum(rng.exponential(1.0 / rate, int(rate * duration))))
    online = make_online_requests(arrivals, prompt_mean=96, prompt_std=24,
                                  max_new_mean=8, slo=SLO(1.0, 0.1),
                                  seed=seed + 1)
    offline = make_offline_corpus(4, 8, doc_len=192, question_len=16,
                                  max_new=4, seed=seed + 2)
    return online + offline


# ------------------------------------------------------------------ metrics
def test_histogram_percentile_interpolation():
    h = Histogram("lat", "", buckets=(0.1, 0.2, 0.4))
    assert h.percentile(0.5) is None, "empty histogram has no quantiles"
    for v in (0.05, 0.15, 0.15, 0.3):
        h.observe(v)
    # p50 target = 2nd sample -> exactly fills the (0.1, 0.2] bucket's
    # first of two counts: 0.1 + 0.5 * (0.2 - 0.1)
    assert h.percentile(0.5) == pytest.approx(0.15)
    assert h.percentile(0.25) == pytest.approx(0.1)    # edge of bucket 0
    assert h.percentile(1.0) == pytest.approx(0.4)
    child = h.labels()
    assert child.count == 4
    assert child.sum == pytest.approx(0.65)


def test_histogram_overflow_bucket_reports_top_bound():
    h = Histogram("lat", "", buckets=(1.0, 2.0))
    h.observe(50.0)
    # the +Inf bucket has no upper edge: report its lower bound rather
    # than inventing a value
    assert h.percentile(0.99) == pytest.approx(2.0)
    assert h.labels().counts == [0, 0, 1]


def test_percentiles_are_monotone_across_quantiles():
    h = Histogram("lat", "", buckets=LATENCY_BUCKETS)
    rng = np.random.default_rng(0)
    for v in rng.exponential(0.3, 500):
        h.observe(float(v))
    p50, p90, p99 = (h.percentile(q) for q in (0.5, 0.9, 0.99))
    assert p50 <= p90 <= p99


def test_registry_prometheus_round_trip():
    r = MetricsRegistry()
    c = r.counter("tokens_total", "tokens", ("task",))
    c.labels("online").inc(5)
    c.labels("offline").inc(2)
    r.gauge("depth", "queue depth").set(3.5)
    h = r.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = r.to_prometheus()
    series = parse_prometheus(text)
    assert ('{task="online"}', 5.0) in series["echo_tokens_total"]
    assert ('{task="offline"}', 2.0) in series["echo_tokens_total"]
    assert series["echo_depth"] == [("", 3.5)]
    # histogram buckets are cumulative and end at +Inf == _count
    buckets = dict(series["echo_lat_seconds_bucket"])
    assert buckets['{le="0.1"}'] == 1
    assert buckets['{le="1"}'] == 2
    assert buckets['{le="+Inf"}'] == 3
    assert series["echo_lat_seconds_count"] == [("", 3.0)]
    assert series["echo_lat_seconds_sum"] == [("", pytest.approx(5.55))]


def test_registry_json_snapshot_round_trips():
    r = MetricsRegistry()
    r.counter("n_total", "n").inc(7)
    h = r.histogram("lat", "l", ("replica",), buckets=(0.5,))
    h.labels("0").observe(0.2)
    snap = json.loads(json.dumps(r.to_json()))
    assert snap["echo_n_total"]["series"][0]["value"] == 7
    hist = snap["echo_lat"]["series"][0]
    assert hist["labels"] == ["0"]
    assert hist["counts"] == [1, 0]
    assert hist["count"] == 1


def test_registry_rejects_shape_change_and_reuses_same_shape():
    r = MetricsRegistry()
    c1 = r.counter("x_total", "x", ("task",))
    assert r.counter("x_total", "x", ("task",)) is c1
    with pytest.raises(ValueError):
        r.counter("x_total", "x", ("replica",))
    with pytest.raises(ValueError):
        r.gauge("x_total", "x", ("task",))


def test_parse_prometheus_rejects_malformed_lines():
    with pytest.raises(ValueError, match="not a valid sample"):
        parse_prometheus("ok_metric 1\nbad metric line here\n")
    with pytest.raises(ValueError, match="no samples"):
        parse_prometheus("# HELP only comments\n")


# ----------------------------------------------------------- fault isolation
def test_event_bus_isolates_poisoned_subscriber(caplog):
    """A raising callback must not take the serving loop down, must be
    counted, and must not starve later subscribers of the same event."""
    service = EchoService(_engine())
    seen = []

    def poisoned(handle):
        raise RuntimeError("subscriber bug")

    service.events.on_finish(poisoned)
    service.events.on_finish(lambda h: seen.append(h.rid))
    workload = _pressure_workload(seed=1, duration=2.0, rate=3.0)
    with caplog.at_level(logging.WARNING, logger="repro.serving.events"):
        stats = service.drive(clone_requests(workload), max_iters=20_000)
    assert len(stats.finished) == len(workload), \
        "a poisoned subscriber must not break serving"
    assert sorted(seen) == sorted(r.rid for r in stats.finished), \
        "subscribers after the poisoned one must still fire"
    assert service.events.dropped_callbacks == len(stats.finished)
    # logged once per (event, callback) pair, not once per event
    warns = [r for r in caplog.records if "subscriber" in r.message]
    assert len(warns) == 1


# ----------------------------------------------------------------- tracing
def test_tracer_lifecycle_coverage_and_zero_cost(tmp_path):
    """The instrumented run must (a) leave the simulation untouched — byte
    for byte the same stats as a bare run — and (b) produce a loadable
    Chrome trace covering preempt and swap lifecycles."""
    workload = _pressure_workload()

    bare = EchoService(_engine())
    want = bare.drive(clone_requests(workload, preserve_rid=True),
                      max_iters=20_000)

    service = EchoService(_engine())
    registry, tracer = MetricsRegistry(), Tracer()
    instrument(service, registry, tracer)
    got = service.drive(clone_requests(workload, preserve_rid=True),
                        max_iters=20_000)

    # zero-cost: tracing must be a pure observer of the virtual clock
    assert len(got.finished) == len(want.finished)
    assert got.offline_throughput() == want.offline_throughput()
    assert got.slo_attainment("ttft") == want.slo_attainment("ttft")
    assert got.swap_transfer_time == want.swap_transfer_time

    assert tracer.preempted_rids(), "workload must exercise preemption"
    assert tracer.swapped_rids(), "workload must exercise host-tier swap-in"
    assert tracer.dropped_events == 0

    path = tmp_path / "trace.json"
    tracer.write(str(path))
    summary = check_trace(str(path))
    assert summary["spans"] > 0 and summary["instants"] > 0
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    names = {e["name"] for e in events}
    for expected in ("schedule", "exec", "queued", "preempt", "parked",
                     "swap-in", "finish", "process_name", "thread_name"):
        assert expected in names, f"missing {expected!r} events"


def test_tracer_ring_buffer_bounds_memory():
    workload = _pressure_workload(seed=2, duration=2.0)
    service = EchoService(_engine())
    tracer = Tracer(cap=100)
    instrument(service, MetricsRegistry(), tracer)
    service.drive(clone_requests(workload), max_iters=20_000)
    assert len(tracer._events) == 100
    assert tracer.dropped_events == tracer.n_recorded - 100 > 0
    # export still yields a valid trace (oldest events dropped, not corrupt)
    d = tracer.to_dict()
    assert d["otherData"]["dropped"] == tracer.dropped_events
    assert sum(1 for e in d["traceEvents"] if e["ph"] == "X") > 0


def test_engine_skips_detail_without_detailed_listener(monkeypatch):
    """The hot path must not build IterationDetail when no listener
    overrides on_iteration — the zero-cost-when-disabled contract."""
    import repro.core.engine as engine_mod
    from repro.core.engine import EngineListener

    class Passive(EngineListener):
        pass                                   # does NOT override on_iteration

    class Boom:
        def __init__(self, *a, **kw):
            raise AssertionError("IterationDetail built on the bare path")

    eng = _engine()
    eng.listeners.append(Passive())
    for r in clone_requests(_pressure_workload(seed=3, duration=1.0)):
        eng.submit(r)
    monkeypatch.setattr(engine_mod, "IterationDetail", Boom)
    eng.run(max_iters=2_000)                   # must never construct Boom

    class Detailed(EngineListener):
        def __init__(self):
            self.details = []

        def on_iteration(self, rec, detail):
            self.details.append(detail)

    monkeypatch.undo()
    eng2 = _engine()
    detailed = Detailed()
    eng2.listeners.append(detailed)
    for r in clone_requests(_pressure_workload(seed=3, duration=1.0)):
        eng2.submit(r)
    eng2.run(max_iters=2_000)
    assert detailed.details, "overriding listener must receive details"
    d = detailed.details[0]
    assert d.t_end >= d.t_start


# ------------------------------------------------------------------- probes
def test_calibrator_residual_tap_fires_for_both_kinds():
    cal = OnlineCalibrator(_tm())
    taps = []
    cal.on_residual = lambda kind, rel: taps.append((kind, rel))
    rel = cal.observe(0.0, [(0, 64)], [8], observed=0.02)
    srel = cal.observe_swap(256, observed=0.004)
    assert ("iter", rel) in taps
    assert ("swap", srel) in taps


def test_engine_probe_populates_drift_metrics(tmp_path):
    eng = _engine(policy=ECHO_C)
    for r in clone_requests(_pressure_workload()):
        eng.submit(r)
    registry = MetricsRegistry()
    instrument_engine(eng, registry, replica=0)
    stats = eng.run(max_iters=20_000)

    assert registry.get("iteration_seconds").labels("0").count == \
        len(stats.iterations)
    plan = registry.get("plan_rel_err").labels("0")
    assert 0 < plan.count <= len(stats.iterations)
    # ECHO_C calibrates: the chained tap must histogram every residual
    est = registry.get("estimator_rel_err")
    assert est.labels("0", "iter").count == eng.calibrator.n_observed > 0
    assert est.labels("0", "swap").count == eng.calibrator.n_swap_observed > 0
    # MemoryPredictor-vs-actual probe and pool gauges track the last state
    snap = eng.bm.occupancy_snapshot()
    kv = registry.get("kv_blocks")
    for state in ("free", "running", "cached"):
        assert kv.labels("0", state).value == snap[state]
    assert kv.labels("0", "host_capacity").value == snap["host_capacity"]
    assert registry.get("mem_pred_rel_err").labels("0").count > 0
    assert registry.get("swap_hidden_frac").labels("0").count > 0

    # the full snapshot survives both expositions
    prom = tmp_path / "m.prom"
    registry.write(str(prom))
    assert check_prometheus(str(prom))["samples"] > 0


def test_probe_chains_existing_residual_tap():
    eng = _engine(policy=ECHO_C)
    prior = []
    eng.calibrator.on_residual = lambda kind, rel: prior.append(kind)
    registry = MetricsRegistry()
    instrument_engine(eng, registry)
    for r in clone_requests(_pressure_workload(seed=5, duration=1.5)):
        eng.submit(r)
    eng.run(max_iters=10_000)
    assert len(prior) == eng.calibrator.n_observed \
        + eng.calibrator.n_swap_observed, \
        "pre-installed tap must keep firing after the probe chains onto it"


# ------------------------------------------------------- live-vs-post-hoc
def test_live_metrics_swap_accounting_matches_post_hoc_engine():
    service = EchoService(_engine())
    stats = service.drive(clone_requests(_pressure_workload(seed=6)),
                          max_iters=20_000)
    live = service.live
    eng = service.engine
    assert live.swapped_in_tokens == eng.bm.metrics.swapped_in_tokens > 0
    assert live.swapped_out_tokens == eng.bm.metrics.swapped_out_tokens
    assert live.swap_transfer_time == pytest.approx(stats.swap_transfer_time)
    assert live.swap_hidden_frac() == pytest.approx(stats.swap_hidden_frac())
    assert live.preemptions == \
        sum(r.n_preemptions for r in stats.finished) > 0
    done_off = [r for r in stats.finished if not r.is_online]
    assert live.completed_offline_tokens == \
        sum(r.prompt_len + r.n_output for r in done_off)


def test_live_metrics_match_post_hoc_on_cluster():
    workload = _pressure_workload(seed=7, duration=5.0, rate=8.0)
    sim = ClusterSimulator(3, ECHO, num_blocks=48, time_model=_tm(),
                           host_kv_blocks=64, seed=0)
    service = EchoService(sim)
    registry, tracer = MetricsRegistry(), Tracer()
    instrument(service, registry, tracer)
    stats = service.drive(clone_requests(workload), until_time=120.0)
    live = service.live
    merged = stats.merged()
    on_done = sum(1 for r in merged.finished if r.is_online)
    assert live.finished_online == on_done
    assert live.finished_offline == len(merged.finished) - on_done
    assert live.slo_attainment("ttft") == stats.slo_attainment("ttft")
    assert live.slo_attainment("tpot") == stats.slo_attainment("tpot")
    swapped_in = sum(e.bm.metrics.swapped_in_tokens
                     for e in service.backend.engines())
    assert live.swapped_in_tokens == swapped_in
    # per-replica probe tracks exist and the iteration counts line up
    it = registry.get("iteration_seconds")
    for i, eng in enumerate(service.backend.engines()):
        assert it.labels(str(i)).count == len(eng.stats.iterations)
    # the router instants land on their own trace process
    d = tracer.to_dict()
    router_events = [e for e in d["traceEvents"]
                     if e["pid"] == 9999 and e["ph"] == "i"]
    assert router_events, "cluster trace must include dispatch instants"


def test_live_percentiles_are_ordered_and_complete():
    service = EchoService(_engine())
    service.drive(clone_requests(_pressure_workload(seed=8)),
                  max_iters=20_000)
    pct = service.live.percentiles()
    for name in ("ttft", "tpot", "queue_delay"):
        assert name in pct, f"{name} missing from percentile table"
        v = pct[name]
        assert v["p50"] <= v["p90"] <= v["p99"]
    assert service.live.percentile("ttft", 0.5) == pct["ttft"]["p50"]


# -------------------------------------------------------------- check tool
def test_check_trace_rejects_invalid_artifacts(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"events": []}))
    with pytest.raises(ValueError, match="traceEvents"):
        check_trace(str(bad))
    nospan = tmp_path / "nospan.json"
    nospan.write_text(json.dumps(
        {"traceEvents": [{"ph": "i", "name": "x", "pid": 0, "tid": 1,
                          "ts": 0.0}]}))
    with pytest.raises(ValueError, match="no complete"):
        check_trace(str(nospan))
    missing = tmp_path / "missing.json"
    missing.write_text(json.dumps(
        {"traceEvents": [{"ph": "X", "name": "x", "pid": 0, "tid": 1}]}))
    with pytest.raises(ValueError, match="missing ts"):
        check_trace(str(missing))


def test_check_prometheus_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.prom"
    bad.write_text("this is { not exposition\n")
    with pytest.raises(ValueError):
        check_prometheus(str(bad))
