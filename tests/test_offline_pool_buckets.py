"""OfflinePool length bucketing: the documented boundary (buckets start at
256 tokens, bucket k = [256*2^k, 256*2^(k+1))) plus monotonicity/coverage
properties. Kept separate from test_radix_pool.py so the deterministic
boundary checks run even where hypothesis is unavailable."""
import pytest

from repro.core.radix_pool import OfflinePool


def test_bucket_boundary_matches_docstring():
    """Regression (satellite 3): a 256-token prompt used to land in bucket
    1, stranding bucket 0 for sub-256 prompts against the docstring."""
    pool = OfflinePool(block_size=16, n_buckets=6)
    assert pool.bucket_of(1) == 0
    assert pool.bucket_of(255) == 0
    assert pool.bucket_of(256) == 0, "doc: buckets start at 256"
    assert pool.bucket_of(511) == 0
    assert pool.bucket_of(512) == 1
    assert pool.bucket_of(1023) == 1
    assert pool.bucket_of(1024) == 2
    for k in range(1, 6):
        assert pool.bucket_of(256 * (1 << k)) == min(k, pool.n_buckets - 1)
    # last bucket is open-ended
    assert pool.bucket_of(10 ** 9) == pool.n_buckets - 1


def test_bucketing_property_monotone_and_total():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 1 << 24), st.integers(0, 1 << 24),
           st.integers(2, 8))
    def prop(a, b, n_buckets):
        pool = OfflinePool(block_size=16, n_buckets=n_buckets)
        ba, bb = pool.bucket_of(a), pool.bucket_of(b)
        # total: every length maps to a valid bucket
        assert 0 <= ba < n_buckets and 0 <= bb < n_buckets
        # monotone: longer prompts never map to a smaller bucket
        if a <= b:
            assert ba <= bb

    prop()
