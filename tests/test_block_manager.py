"""BlockManager unit + property tests (§4.2 semantics)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.block_manager import BlockManager, chain_hash
from repro.core.request import Request, TaskType


def _req(tokens, task=TaskType.OFFLINE, max_new=4):
    r = Request(prompt=tuple(tokens), max_new_tokens=max_new, task_type=task)
    r.admit()
    return r


def test_prefix_probe_and_hit():
    bm = BlockManager(16, 4)
    r1 = _req(range(12))
    assert bm.allocate(r1, 12, r1.full_tokens, 0.0) == 0
    r1.computed_tokens = 12
    bm.commit(r1, r1.full_tokens, 0.0)
    assert bm.probe_prefix(tuple(range(12))) == 12
    assert bm.probe_prefix(tuple(range(8))) == 8
    assert bm.probe_prefix(tuple(range(4)) + (99, 98, 97, 96)) == 4
    r2 = _req(tuple(range(8)) + (55, 56, 57, 58))
    hits = bm.allocate(r2, 12, r2.full_tokens, 1.0)
    assert hits == 8
    assert bm.metrics.hit_blocks == 2


def test_only_leading_prefix_hits():
    bm = BlockManager(16, 4)
    r1 = _req(range(8))
    bm.allocate(r1, 8, r1.full_tokens, 0.0)
    r1.computed_tokens = 8
    bm.commit(r1, r1.full_tokens, 0.0)
    # different first block, same second block content: must NOT hit
    r2 = _req((9, 9, 9, 9) + tuple(range(4, 8)))
    hits = bm.allocate(r2, 8, r2.full_tokens, 1.0)
    assert hits == 0


def test_priority_eviction_order():
    """rc>0 offline outlives finished-online outlives dead offline."""
    bm = BlockManager(3, 4, task_aware=True, rc_provider=lambda h: 0)
    # dead offline block
    r_off = _req(range(4))
    bm.allocate(r_off, 4, r_off.full_tokens, 0.0)
    r_off.computed_tokens = 4
    bm.commit(r_off, r_off.full_tokens, 0.0)
    bm.free_request(r_off, 1.0, finished=True)
    # finished online block (newer LAT)
    r_on = _req((50, 51, 52, 53), TaskType.ONLINE)
    bm.allocate(r_on, 4, r_on.full_tokens, 2.0)
    r_on.computed_tokens = 4
    bm.commit(r_on, r_on.full_tokens, 2.0)
    bm.free_request(r_on, 3.0, finished=True)
    # rc>0 offline block (oldest LAT -> LRU would evict it first!)
    rc_map = {}
    bm.rc_provider = lambda h: rc_map.get(h, 0)
    r_shared = _req((70, 71, 72, 73))
    bm.allocate(r_shared, 4, r_shared.full_tokens, 0.5)
    r_shared.computed_tokens = 4
    bm.commit(r_shared, r_shared.full_tokens, 0.5)
    h = chain_hash(0, (70, 71, 72, 73))
    rc_map[h] = 3
    bm.free_request(r_shared, 0.6, finished=True)

    # allocate a new request needing 2 blocks: must evict dead offline first,
    # then finished online; the rc>0 block must survive
    r_new = _req((90, 91, 92, 93, 94, 95, 96, 97))
    assert bm.allocate(r_new, 8, r_new.full_tokens, 5.0) is not None
    assert h in bm.hash_to_bid, "rc>0 offline block must be retained"
    assert bm.metrics.evictions == 2


def test_lru_mode_ignores_priorities():
    bm = BlockManager(2, 4, task_aware=False, rc_provider=lambda h: 99)
    r1 = _req(range(4))
    bm.allocate(r1, 4, r1.full_tokens, 0.0)
    r1.computed_tokens = 4
    bm.commit(r1, r1.full_tokens, 0.0)
    bm.free_request(r1, 1.0, finished=True)
    r2 = _req((9, 8, 7, 6), TaskType.ONLINE)
    bm.allocate(r2, 4, r2.full_tokens, 2.0)
    r2.computed_tokens = 4
    bm.commit(r2, r2.full_tokens, 2.0)
    bm.free_request(r2, 3.0, finished=True)
    # LRU: evicts r1's block (older) regardless of rc
    r3 = _req((1, 2, 3, 4))
    bm.allocate(r3, 4, r3.full_tokens, 4.0)
    h1 = chain_hash(0, (0, 1, 2, 3))
    h2 = chain_hash(0, (9, 8, 7, 6))
    assert h1 not in bm.hash_to_bid
    assert h2 in bm.hash_to_bid


def test_threshold_blocks_running_growth():
    bm = BlockManager(8, 4, task_aware=True)
    bm.threshold_blocks = 2
    r = _req(range(16))
    res = bm.allocate(r, 16, r.full_tokens, 0.0, respect_threshold=True)
    assert res is None, "threshold must reject growth beyond cap"
    assert len(r.block_ids) == 0, "failed allocation must roll back"
    res = bm.allocate(r, 16, r.full_tokens, 0.0, respect_threshold=False)
    assert res is not None


def test_punishment_accounting():
    rc_map = {}
    bm = BlockManager(1, 4, task_aware=True, rc_provider=lambda h: rc_map.get(h, 0))
    r1 = _req(range(4))
    bm.allocate(r1, 4, r1.full_tokens, 0.0)
    r1.computed_tokens = 4
    bm.commit(r1, r1.full_tokens, 0.0)
    h = chain_hash(0, (0, 1, 2, 3))
    rc_map[h] = 2
    bm.free_request(r1, 1.0, finished=True)
    r2 = _req((9, 9, 9, 9))
    bm.allocate(r2, 4, r2.full_tokens, 2.0)
    assert bm.metrics.punished_tokens == 4   # evicted block was needed (rc=2)


# ---------------------------------------------------------------- property
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),            # doc id
                          st.integers(1, 30),           # prompt len
                          st.booleans()),               # online?
                min_size=1, max_size=12),
       st.integers(2, 5))
def test_block_manager_invariants(reqs_spec, bs):
    """No double allocation; ref counts consistent; free+used+cached == total."""
    bm = BlockManager(24, bs)
    live = []
    now = 0.0
    for doc, plen, online in reqs_spec:
        now += 1.0
        prompt = tuple([doc] * bs + list(range(100 + doc, 100 + doc + plen)))
        r = _req(prompt, TaskType.ONLINE if online else TaskType.OFFLINE)
        res = bm.allocate(r, len(prompt), r.full_tokens, now)
        if res is None:
            continue
        r.computed_tokens = len(prompt)
        bm.commit(r, r.full_tokens, now)
        live.append(r)
        # invariant: a block id referenced by two requests must be a shared
        # (hashed) block; unhashed blocks belong to exactly one request
        owners = {}
        for lr in live:
            for bid in lr.block_ids:
                owners.setdefault(bid, []).append(lr.rid)
        for bid, rids in owners.items():
            blk = bm.blocks[bid]
            assert blk.ref == len(rids)
            if len(rids) > 1:
                assert blk.hash is not None
        # invariant: used + free + evictable == total
        used = sum(1 for b in bm.blocks if b.ref > 0)
        assert used + bm.free_blocks + bm.evictable_count() == bm.num_blocks
        # occasionally finish one
        if len(live) > 3:
            done = live.pop(0)
            bm.free_request(done, now, finished=True)
