"""Workload + trace generators mirror the paper's characteristics."""
import numpy as np

from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.data.workload import sharing_rate
from repro.core.simulator import estimate_capacity
from repro.core import SLO, TimeModel


def test_offline_sharing_rate_high():
    offline = make_offline_corpus(6, 8, doc_len=256, question_len=32)
    rate = sharing_rate(offline, block_size=16)
    assert rate > 0.8, rate                 # Table 1: LooGLE ~91%


def test_online_sharing_rate_low():
    online = make_online_requests(np.arange(40) * 0.1, prompt_mean=300,
                                  prompt_std=80)
    rate = sharing_rate(online, block_size=16)
    assert rate < 0.05, rate                # Table 1: ShareGPT < 5%


def test_trace_tidal_ratio():
    tr = BurstyTrace(base_rate=2.0, tidal_period=1000.0, tidal_ratio=6.0)
    peak = tr.rate(500.0)                   # sin peak at T/2
    trough = tr.rate(0.0)                   # trough at 0
    assert peak / trough > 4.0


def test_trace_sampling_rate_plausible():
    tr = BurstyTrace(base_rate=5.0, tidal_period=1e9, burst_rate=1.0, seed=1)
    arr = tr.sample(0, 200)
    got = len(arr) / 200
    want = np.mean([tr.rate(t) for t in np.linspace(0, 200, 50)])
    assert 0.6 * want < got < 1.6 * want


def test_capacity_estimation_monotone():
    """§5.4 Step 1: more blocks -> SLO attainment never decreases much;
    the report picks the smallest passing size."""
    tm = TimeModel(alpha=2e-7, beta=1e-4, c=2e-3, gamma=3e-5, delta=3e-5,
                   d0=2e-3, lam=0.9)
    online = make_online_requests(np.arange(0, 10, 0.25),
                                  prompt_mean=96, prompt_std=16,
                                  max_new_mean=16, slo=SLO(1.0, 0.1))
    offline = make_offline_corpus(2, 4, doc_len=64, question_len=16, max_new=8)
    rep = estimate_capacity(online, offline, tm,
                            candidate_blocks=(16, 64, 256),
                            slo_target=0.9, duration=20.0)
    assert rep.min_blocks_for_slo is not None
    atts = [a for _, a in rep.slo_by_blocks]
    assert atts[-1] >= atts[0] - 0.05
    assert rep.offline_throughput is None or rep.offline_throughput >= 0
