"""End-to-end engine correctness: the paged serving path must generate the
same greedy tokens as the dense reference path; policies run to completion;
prefix sharing yields identical outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALL_POLICIES, BS, ECHO, SLO, EchoEngine, Request,
                        TaskType, TimeModel)
from repro.data import make_offline_corpus, make_online_requests


def _reference_generate(model, params, prompt, n_new):
    """Dense-path greedy generation oracle."""
    toks = jnp.asarray([prompt], jnp.int32)
    last, cache = model.prefill(params, toks)
    total = len(prompt) + n_new + 1
    cache = model.pad_cache(cache, len(prompt), total)
    out = []
    cur = int(jnp.argmax(last[0]))
    out.append(cur)
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = model.decode_step(params, jnp.asarray([cur], jnp.int32),
                                      cache, jnp.asarray([pos], jnp.int32))
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    return out


@pytest.fixture(scope="module")
def engine_model(tiny_cfg):
    from repro.models import Model
    m = Model(tiny_cfg)
    return m, m.init(jax.random.PRNGKey(0))


def test_engine_matches_reference_generation(engine_model):
    model, params = engine_model
    rng = np.random.default_rng(0)
    prompts = [tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, n))
               for n in (13, 25, 40)]
    n_new = 6
    eng = EchoEngine(model, params, ECHO, num_blocks=64, block_size=8,
                     chunk_size=16, max_pages_per_seq=16)
    reqs = [Request(prompt=p, max_new_tokens=n_new,
                    task_type=TaskType.OFFLINE) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run(max_iters=500)
    for r, p in zip(reqs, prompts):
        want = _reference_generate(model, params, p, n_new)
        assert r.output_tokens == want, \
            f"paged engine diverged from dense reference for len={len(p)}"


def test_prefix_sharing_preserves_outputs(engine_model):
    """Two requests sharing a prefix must produce the same tokens as when
    run alone (cache reuse must not change results)."""
    model, params = engine_model
    rng = np.random.default_rng(1)
    doc = tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, 24))
    q1 = tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, 8))
    q2 = tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, 8))
    n_new = 5

    eng = EchoEngine(model, params, ECHO, num_blocks=64, block_size=8,
                     chunk_size=16, max_pages_per_seq=16)
    r1 = Request(prompt=doc + q1, max_new_tokens=n_new, task_type=TaskType.OFFLINE)
    r2 = Request(prompt=doc + q2, max_new_tokens=n_new, task_type=TaskType.OFFLINE)
    eng.submit(r1)
    eng.submit(r2)
    eng.run(max_iters=500)
    assert eng.bm.metrics.hit_blocks > 0, "prefix must actually be shared"
    assert r1.output_tokens == _reference_generate(model, params, doc + q1, n_new)
    assert r2.output_tokens == _reference_generate(model, params, doc + q2, n_new)


def test_preemption_recompute_preserves_outputs(engine_model):
    """Force preemption via tiny memory; outputs must still match."""
    model, params = engine_model
    rng = np.random.default_rng(2)
    offp = tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, 40))
    onp = tuple(int(x) for x in rng.integers(0, model.cfg.vocab_size, 40))
    off = Request(prompt=offp, max_new_tokens=6, task_type=TaskType.OFFLINE)
    on = Request(prompt=onp, max_new_tokens=6, task_type=TaskType.ONLINE,
                 arrival_time=0.002, slo=SLO(10, 10))
    eng = EchoEngine(model, params, ECHO, num_blocks=14, block_size=8,
                     chunk_size=16, max_pages_per_seq=16)
    eng.submit(off)
    eng.submit(on)
    eng.run(max_iters=1000)
    assert off.done and on.done
    assert off.output_tokens == _reference_generate(model, params, offp, 6)
    assert on.output_tokens == _reference_generate(model, params, onp, 6)


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_all_policies_complete(engine_model, policy):
    model, params = engine_model
    online = make_online_requests([0.01, 0.3], prompt_mean=24, prompt_std=4,
                                  max_new_mean=4, vocab=model.cfg.vocab_size)
    offline = make_offline_corpus(2, 2, doc_len=32, question_len=8, max_new=4,
                                  vocab=model.cfg.vocab_size)
    eng = EchoEngine(model, params, policy, num_blocks=64, block_size=8,
                     chunk_size=16, max_pages_per_seq=16)
    for r in online + offline:
        eng.submit(r)
    stats = eng.run(max_iters=2000)
    assert len(stats.finished) == len(online) + len(offline)
    assert all(r.done for r in stats.finished)


def test_slo_attainment_excludes_undecidable():
    """Regression: a request whose metric is undefined (tpot with <2 output
    tokens; ttft with no first token) must be excluded from the denominator
    in BOTH branches — tpot used to count it as attained while ttft counted
    it as a miss."""
    from repro.core import EngineStats

    def _req(n_out, slow=False):
        r = Request(prompt=(1, 2, 3), max_new_tokens=max(n_out, 1),
                    task_type=TaskType.ONLINE, arrival_time=0.0,
                    slo=SLO(ttft=1.0, tpot=0.1))
        step = 1.0 if slow else 0.05
        for i in range(n_out):
            r.record_token(7, 0.5 + i * step)
        return r

    stats = EngineStats()
    stats.finished = [_req(4), _req(4, slow=True), _req(1)]  # hit, miss, n/a
    assert stats.slo_attainment("tpot") == 0.5   # 1 of 2 decidable
    assert stats.slo_attainment("ttft") == 1.0   # all 3 decidable, all hit

    # undecidable ttft (never emitted): excluded, not a miss
    ghost = Request(prompt=(1,), max_new_tokens=1, task_type=TaskType.ONLINE,
                    arrival_time=0.0, slo=SLO(1.0, 0.1))
    stats.finished.append(ghost)
    assert stats.slo_attainment("ttft") == 1.0

    # all-undecidable: vacuous attainment, not a division crash
    only = EngineStats()
    only.finished = [_req(1)]
    assert only.slo_attainment("tpot") == 1.0


def test_simulator_mode_runs_and_orders():
    tm = TimeModel(alpha=2e-7, beta=1e-4, c=2e-3, gamma=3e-5, delta=3e-5,
                   d0=2e-3, lam=0.9)
    offline = make_offline_corpus(4, 6, doc_len=96, question_len=16, max_new=8)
    tputs = {}
    for pol in (BS, ECHO):
        eng = EchoEngine(None, None, pol, num_blocks=128, block_size=16,
                         chunk_size=32, time_model=tm)
        for r in make_offline_corpus(4, 6, doc_len=96, question_len=16,
                                     max_new=8):
            eng.submit(r)
        stats = eng.run(max_iters=5000)
        assert sum(1 for r in stats.finished if not r.is_online) == 24
        tputs[pol.name] = stats.offline_throughput()
    # Echo (KV-aware + reuse) must not be slower than BS on a shared corpus
    assert tputs["Echo"] >= tputs["BS"] * 0.95, tputs


def test_ssm_state_snapshot_engine_matches_reference():
    """Attention-free (mamba2) engine path: state-snapshot prefix caching
    must reuse shared prefixes AND generate exactly the dense-path tokens."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("mamba2-1.3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)

    def ref_gen(prompt, n_new):
        toks = jnp.asarray([prompt], jnp.int32)
        last, cache = model.prefill(params, toks)
        out = [int(jnp.argmax(last[0]))]
        pos = len(prompt)
        for _ in range(n_new - 1):
            lg, cache = model.decode_step(
                params, jnp.asarray([out[-1]], jnp.int32), cache,
                jnp.asarray([pos], jnp.int32))
            out.append(int(jnp.argmax(lg[0])))
            pos += 1
        return out

    doc = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 48))
    qs = [tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 9))
          for _ in range(2)]
    eng = EchoEngine(model, params, ECHO, num_blocks=64,
                     block_size=cfg.ssm_chunk, chunk_size=32,
                     max_pages_per_seq=16)
    reqs = [Request(prompt=doc + q, max_new_tokens=5,
                    task_type=TaskType.OFFLINE) for q in qs]
    for r in reqs:
        eng.submit(r)
    eng.run(max_iters=500)
    assert eng.bm.metrics.hit_blocks > 0, "snapshot prefix must be reused"
    for r, q in zip(reqs, qs):
        assert r.output_tokens == ref_gen(doc + q, 5)


def test_hybrid_state_snapshot_engine_matches_reference():
    """Hybrid (recurrentgemma) engine path: RG-LRU states + window-KV rings
    snapshot at block boundaries; tokens must match the dense path."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("recurrentgemma-9b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)

    def ref_gen(prompt, n_new):
        toks = jnp.asarray([prompt], jnp.int32)
        last, cache = model.prefill(params, toks)
        cache = model.pad_cache(cache, len(prompt), len(prompt) + n_new + 1)
        out = [int(jnp.argmax(last[0]))]
        pos = len(prompt)
        for _ in range(n_new - 1):
            lg, cache = model.decode_step(
                params, jnp.asarray([out[-1]], jnp.int32), cache,
                jnp.asarray([pos], jnp.int32))
            out.append(int(jnp.argmax(lg[0])))
            pos += 1
        return out

    doc = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 32))
    qs = [tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 7))
          for _ in range(2)]
    eng = EchoEngine(model, params, ECHO, num_blocks=64, block_size=16,
                     chunk_size=16, max_pages_per_seq=16)
    reqs = [Request(prompt=doc + q, max_new_tokens=4,
                    task_type=TaskType.OFFLINE) for q in qs]
    for r in reqs:
        eng.submit(r)
    eng.run(max_iters=800)
    assert eng.bm.metrics.hit_blocks > 0
    for r, q in zip(reqs, qs):
        assert r.output_tokens == ref_gen(doc + q, 4)
