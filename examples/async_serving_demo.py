"""Real-time serving demo: the asyncio front door over the same engine
the trace benchmarks drive.

Concurrent streaming clients, a mid-stream cancellation, admission
backpressure, and a graceful drain — all on the model-free virtual-clock
engine so the demo runs anywhere in milliseconds. Swap ``None, None`` for
a real ``Model`` + params (see examples/quickstart.py) to serve actual
forward passes with the identical code.

    PYTHONPATH=src python examples/async_serving_demo.py
"""
import asyncio

from repro.core import ECHO, SLO, EchoEngine, TimeModel
from repro.serving import AdmissionConfig
from repro.rt import AsyncEchoEngine


def make_engine() -> EchoEngine:
    return EchoEngine(None, None, ECHO, num_blocks=128, block_size=16,
                      chunk_size=32, time_model=TimeModel.a100())


async def stream_one(rt: AsyncEchoEngine, name: str, prompt, n: int) -> None:
    """One client: submit, stream tokens as the loop produces them."""
    h = await rt.submit(prompt, max_new_tokens=n, slo=SLO(1.0, 0.1))
    async for ev in h.tokens():
        if ev.first:
            print(f"  {name}: first token after {h.wall_ttft()*1e3:.1f}ms "
                  f"wall ({ev.t_engine:.3f}s engine clock)")
    print(f"  {name}: {h.n_tokens} tokens, status {h.status.value}")


async def main() -> None:
    rt = AsyncEchoEngine(make_engine(),
                         admission=AdmissionConfig(max_online_queue=32))
    registry = rt.instrument()              # wall-clock TTFT/TPOT histograms

    async with rt:                          # start() ... graceful drain()
        # -- a burst of concurrent streaming clients ---------------------
        print("8 concurrent online clients + 4 offline background jobs:")
        offline = [await rt.submit([200 + i] * 64, task_type="offline",
                                   max_new_tokens=16) for i in range(4)]
        await asyncio.gather(*[
            stream_one(rt, f"client{i}", [100 + i, 1, 2, 3], 6)
            for i in range(8)])

        # -- mid-stream cancellation ------------------------------------
        victim = await rt.submit([7] * 32, max_new_tokens=200)
        count = 0
        async for _ev in victim.tokens():
            count += 1
            if count == 3:                  # changed our mind
                await victim.abort()        # KV blocks freed immediately
        print(f"aborted after {count} tokens: status {victim.status.value}")

        for h in offline:
            res = await h.result()
            print(f"  offline rid={h.rid}: {res.status.value}, "
                  f"{len(res.tokens)} tokens")

    # the context manager drained: in-flight work finished, stager flushed
    print(f"drained: state={rt.state.value}  "
          f"finished={rt.stats.finished} aborted={rt.stats.aborted}")
    leaks = rt.kv_leaks()
    print(f"kv leaks after drain: "
          f"{'none' if not any(leaks.values()) else leaks}")
    p99 = registry.get("rt_ttft_wall_seconds").percentile(0.99)
    print(f"wall TTFT p99: {p99*1e3:.1f}ms")


if __name__ == "__main__":
    asyncio.run(main())
