"""Train a reduced model for a few hundred steps (the train_4k substrate
at CPU scale): data pipeline -> AdamW -> checkpoint.

    PYTHONPATH=src python examples/train_tiny.py [--arch mamba2-1.3b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.training import adamw_init, make_train_step
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
step = jax.jit(make_train_step(model, total_steps=args.steps))
stream = TokenStream(cfg.vocab_size, seed=0)
mm = cfg.mm_embed_dim if cfg.multimodal else None

for i, batch in enumerate(stream.batches(4, 64, mm)):
    params, opt, m = step(params, opt,
                          {k: jnp.asarray(v) for k, v in batch.items()})
    if i % 25 == 0:
        print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
              f"lr {float(m['lr']):.2e}")
    if i + 1 >= args.steps:
        break
ckpt.save("/tmp/repro_tiny_ckpt", params, step=args.steps)
print("checkpoint saved to /tmp/repro_tiny_ckpt.npz")
