"""§5.4 estimation toolkit for system deployers: find the minimum KV-cache
size meeting online SLOs at peak load, then the offline throughput the
chosen deployment sustains.

    PYTHONPATH=src python examples/capacity_planning.py
"""
from repro.core import SLO, TimeModel
from repro.core.simulator import estimate_capacity
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests

tm = TimeModel(alpha=2e-7, beta=1e-4, c=2e-3, gamma=3e-5, delta=3e-5,
               d0=2e-3, lam=0.9)

# peak-window online workload (Step 1 simulates ~5 minutes of peak)
trace = BurstyTrace(base_rate=4.0, tidal_period=600.0, burst_rate=6.0,
                    burst_len=8.0, burst_prob=0.05, seed=0)
online_peak = make_online_requests(trace.sample(0, 30.0), prompt_mean=128,
                                   prompt_std=32, max_new_mean=24,
                                   slo=SLO(1.0, 0.1), seed=1)
offline = make_offline_corpus(8, 16, doc_len=256, question_len=32,
                              max_new=16, seed=2)

report = estimate_capacity(online_peak, offline, tm,
                           candidate_blocks=(32, 64, 128, 256, 512),
                           slo_target=0.9, duration=30.0)
print("candidate KV sizes vs online SLO attainment:")
for nb, att in report.slo_by_blocks:
    print(f"  {nb:5d} blocks -> {att:.3f}")
print(f"minimum blocks meeting SLOs : {report.min_blocks_for_slo}")
print(f"offline throughput there    : {report.offline_throughput:.1f} tok/s")
