"""Fleet elasticity demo: an autoscaled fleet rides an online burst, then a
replica is killed mid-run and the fleet recovers the stranded work.

Scale-up: a FleetController starts the fleet at one replica; when the
bursty online trace spikes, its RatePredictor (mu + k*sigma over a sliding
window) plus a queue-depth backstop add JOINING replicas that come up after
a join delay. Chaos: ChaosConfig kills replica 0 mid-burst — its KV (device
and host tier) is lost, and every in-flight request is re-dispatched with
recompute semantics, online first. The lifecycle log and the kill's
recovery record show both mechanisms end to end.

    PYTHONPATH=src python examples/fleet_elasticity_demo.py
"""
from repro.cluster import ChaosConfig, ClusterSimulator, FleetController
from repro.core import ECHO, SLO, TimeModel
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests

tm = TimeModel.a100()
DURATION = 20.0

# bursty online trace: quiet baseline with flash crowds the static sizing
# would have to over-provision for
trace = BurstyTrace(base_rate=2.0, burst_rate=12.0, burst_len=5.0,
                    burst_prob=0.12, tidal_period=2 * DURATION, seed=7)
online = make_online_requests(trace.sample(0, DURATION), prompt_mean=160,
                              prompt_std=40, max_new_mean=32,
                              slo=SLO(1.0, 0.1), seed=1)
offline = make_offline_corpus(4, 24, doc_len=320, question_len=32,
                              max_new=16, seed=2)

controller = FleetController(min_replicas=1, max_replicas=3,
                             rate_per_replica=4.0, interval=1.0,
                             cooldown=2.0, queue_high=2, bin_s=2.0)
chaos = ChaosConfig(kills=[(DURATION * 0.4, 0)])

sim = ClusterSimulator(1, ECHO, num_blocks=96, host_kv_blocks=128,
                       time_model=tm, seed=0, autoscaler=controller,
                       chaos=chaos, join_delay=0.5)
sim.submit_all(online + offline)
stats = sim.run(until_time=DURATION * 6)

print(f"workload: {len(online)} online + {len(offline)} offline over "
      f"{DURATION:.0f}s (burst to {trace.burst_rate:.0f} req/s)")
print("lifecycle:")
for t, rid, state in stats.lifecycle:
    print(f"  t={t:6.2f}  replica {rid} -> {state}")
for k in stats.kills:
    print(f"kill @ t={k.t:.2f}: replica {k.replica_id} lost "
          f"{k.lost_tokens} KV tokens; re-dispatched "
          f"{k.redispatched_online} online + {k.redispatched_offline} "
          f"offline")
lat = stats.recovery_latencies()
on, off = stats.finished_counts()
print(f"finished {on}/{len(online)} online, {off}/{len(offline)} offline  "
      f"TTFT SLO {stats.slo_attainment('ttft'):.3f}  "
      f"fleet cost {stats.replica_seconds:.1f} replica-seconds")
if lat:
    print(f"recovery: {len(lat)} re-dispatched requests finished, "
          f"worst {max(lat):.2f}s after the kill")
print(f"autoscaler: +{controller.n_added} added, "
      f"-{controller.n_drained} drained "
      f"(decisions: {[(round(t, 1), op, k) for t, op, k in controller.decisions]})")

assert on == len(online) and off == len(offline), "lost requests"
assert stats.kills and stats.kills[0].rids, "kill re-dispatched nothing"
assert controller.n_added > 0, "autoscaler never scaled up"
print("ok: burst absorbed, kill recovered, every request finished")
