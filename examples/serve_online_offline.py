"""End-to-end co-serving driver: a real (reduced) model served for hundreds
of engine iterations against a bursty online trace + LooGLE-like offline
batch, comparing Echo against the vLLM-style baseline — driven through the
EchoService facade with event-bus live metrics instead of post-hoc scraping.

    PYTHONPATH=src python examples/serve_online_offline.py [--arch qwen3-4b]
"""
import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import BS, ECHO, SLO, EchoEngine, TimeModel
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.models import Model
from repro.serving import EchoService

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
ap.add_argument("--duration", type=float, default=20.0)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
tm = TimeModel(alpha=2e-7, beta=1e-4, c=2e-3, gamma=3e-5, delta=3e-5, d0=2e-3)

for policy in (BS, ECHO):
    trace = BurstyTrace(base_rate=1.5, tidal_period=2 * args.duration,
                        burst_rate=6.0, burst_len=5.0, seed=1)
    online = make_online_requests(trace.sample(0, args.duration),
                                  prompt_mean=48, prompt_std=16,
                                  max_new_mean=12, vocab=cfg.vocab_size,
                                  slo=SLO(1.0, 0.1), seed=2)
    offline = make_offline_corpus(n_docs=5, questions_per_doc=6, doc_len=128,
                                  question_len=16, max_new=8,
                                  vocab=cfg.vocab_size, seed=3)
    eng = EchoEngine(model, params, policy, num_blocks=160, block_size=16,
                     chunk_size=32, max_pages_per_seq=16, time_model=tm)
    service = EchoService(eng)
    stats = service.drive(online + offline, max_iters=20_000,
                          until_time=4 * args.duration)
    live = service.live                  # accumulated from on_token/on_finish
    print(f"--- {policy.name} ---")
    print(f"  iterations         : {len(stats.iterations)}")
    print(f"  offline throughput : {stats.offline_throughput():.1f} tok/s (virtual)")
    print(f"  SLO attainment     : TTFT {live.slo_attainment('ttft'):.3f} "
          f"TPOT {live.slo_attainment('tpot'):.3f}  (live, event-driven)")
    print(f"  preemptions seen   : {live.preemptions}  "
          f"first tokens {live.first_tokens}")
    print(f"  offline hit rate   : {eng.bm.metrics.offline_hit_rate:.3f}")
    print(f"  punished tokens    : {eng.bm.metrics.punished_tokens}")
