"""Cluster quickstart: co-serve two tenants across 2 replicas and compare
the prefix-affinity router against round-robin dispatch — the same
EchoService facade as the single-engine quickstart, routing hidden behind it.

    PYTHONPATH=src python examples/cluster_quickstart.py
"""
from repro.cluster import ClusterSimulator
from repro.core import ECHO, TimeModel
from repro.core.simulator import clone_requests
from repro.data import TenantSpec, make_multi_tenant_workload
from repro.serving import EchoService

tm = TimeModel.a100()

# two tenants, each with a private shared-prefix document corpus; fleet
# working set (2 x 4 docs x 16 blocks) exceeds one replica's 96-block cache
tenants = (TenantSpec("chat", online_rate=1.0, n_docs=4, questions_per_doc=16),
           TenantSpec("batch", online_rate=0.5, n_docs=4, questions_per_doc=16))
online, offline = make_multi_tenant_workload(tenants, duration=15.0, seed=0)

for policy in ("affinity", "round_robin"):
    sim = ClusterSimulator(2, ECHO, router_policy=policy, num_blocks=96,
                           time_model=tm, seed=0)
    service = EchoService(sim)
    stats = service.drive(clone_requests(online) + clone_requests(offline),
                          until_time=60.0)
    on, off = stats.finished_counts()
    print(f"[{policy:>11}] online {on}/{len(online)}  "
          f"offline {off}/{len(offline)}  "
          f"fleet offline tput {stats.offline_throughput():8.1f} tok/s  "
          f"TTFT SLO {stats.slo_attainment('ttft'):.3f}")
    for rep in sim.replicas:
        served = stats.router.per_replica_offline.get(rep.id, 0)
        print(f"    replica {rep.id}: offline dispatched {served:3d}  "
              f"prefix-cache hit rate {rep.engine.bm.metrics.hit_rate:.3f}")
