"""Calibration demo: the scheduler's time model vs. a drifted ground truth.

The engine's scheduler starts from the stock A100 estimate while the
actual hardware clock runs 2x slower with per-iteration jitter. A static
estimate stays ~50% wrong forever; with --calibrate-style online refitting
(`Echo+C`) the estimate converges onto the observed clock within a few
hundred iterations, and the scheduler's SLO gating + offline admission
decisions are priced correctly again.

    PYTHONPATH=src python examples/calibration_demo.py
"""
from repro.core import (ECHO, ECHO_C, SLO, EchoEngine, OnlineCalibrator,
                        TimeModel)
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.serving import EchoService


def build(policy):
    estimate = TimeModel.a100()                       # what the scheduler thinks
    clock = TimeModel.a100().perturbed(scale=2.0,     # what the hardware does
                                       jitter=0.02, seed=7)
    eng = EchoEngine(None, None, policy, num_blocks=256, block_size=16,
                     chunk_size=64, time_model=estimate, clock_model=clock,
                     max_running=48)
    trace = BurstyTrace(base_rate=3.0, tidal_period=120.0, seed=10)
    online = make_online_requests(trace.sample(0, 60.0), prompt_mean=160,
                                  prompt_std=40, max_new_mean=24,
                                  slo=SLO(0.6, 0.05), seed=20)
    offline = make_offline_corpus(10, 96, doc_len=320, question_len=32,
                                  max_new=16, seed=30)
    return eng, online + offline


for name, policy in (("static (Echo)", ECHO), ("calibrated (Echo+C)", ECHO_C)):
    eng, workload = build(policy)
    if eng.calibrator is None:        # measure error without refitting
        eng.calibrator = OnlineCalibrator.passive(eng.tm)
    stats = EchoService(eng).drive(workload, max_iters=60_000,
                                   until_time=360.0)
    cal = eng.calibrator
    print(f"[{name}]")
    print(f"  estimate error: start "
          f"{cal.convergence_curve(100)[0][1]:.1%} -> "
          f"last-100 {cal.mean_rel_err(100):.1%}  (refits: {cal.refits})")
    print(f"  SLO attainment: TTFT {stats.slo_attainment('ttft'):.3f}  "
          f"TPOT {stats.slo_attainment('tpot'):.3f}")
    print(f"  offline throughput: {stats.offline_throughput():.0f} tok/s")
