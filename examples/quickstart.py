"""Quickstart: serve a tiny model through the EchoService API —
co-scheduling online + offline, streaming the online tokens live.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core import ECHO, SLO, EchoEngine, TimeModel
from repro.models import Model
from repro.serving import EchoService

cfg = get_config("qwen3-4b").reduced()          # 2 layers, CPU-runnable
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = EchoEngine(model, params, ECHO, num_blocks=128, block_size=16,
                    chunk_size=32, max_pages_per_seq=16,
                    time_model=TimeModel(alpha=2e-7, beta=1e-4, c=2e-3,
                                         gamma=3e-5, delta=3e-5, d0=2e-3))
service = EchoService(engine)

# one latency-sensitive online request ...
online = service.submit(tuple(range(100, 140)), task_type="online",
                        max_new_tokens=8, slo=SLO(1.0, 0.1), arrival_time=0.0)
# ... and an offline batch sharing a document prefix
doc = tuple(range(200, 296))
offline = [service.submit(doc + tuple(range(300 + 10 * i, 308 + 10 * i)),
                          task_type="offline", max_new_tokens=8)
           for i in range(4)]

# stream the online answer: each iteration of tokens() drives the service
# until the next token lands, interleaved with the offline batch
for ev in online.tokens():
    print(f"online token[{ev.index}] = {ev.token}  (t={ev.t:.3f}s)")
print(f"online TTFT {online.ttft():.3f}s  status {online.status.value}")

stats = service.run()                           # drain the offline work
for i, h in enumerate(offline):
    print(f"offline[{i}]    : {h.result().tokens}")
print(f"offline throughput : {stats.offline_throughput():.1f} tok/s (virtual)")
print(f"prefix cache hit   : {engine.bm.metrics.offline_hit_rate:.2%} "
      f"(doc prefix reused across the batch)")
