"""Quickstart: serve a tiny model with Echo, co-scheduling online + offline.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core import ECHO, SLO, EchoEngine, Request, TaskType, TimeModel
from repro.models import Model

cfg = get_config("qwen3-4b").reduced()          # 2 layers, CPU-runnable
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = EchoEngine(model, params, ECHO, num_blocks=128, block_size=16,
                    chunk_size=32, max_pages_per_seq=16,
                    time_model=TimeModel(alpha=2e-7, beta=1e-4, c=2e-3,
                                         gamma=3e-5, delta=3e-5, d0=2e-3))

# one latency-sensitive online request ...
online = Request(prompt=tuple(range(100, 140)), max_new_tokens=8,
                 task_type=TaskType.ONLINE, arrival_time=0.0, slo=SLO(1.0, 0.1))
# ... and an offline batch sharing a document prefix
doc = tuple(range(200, 296))
offline = [Request(prompt=doc + tuple(range(300 + 10 * i, 308 + 10 * i)),
                   max_new_tokens=8, task_type=TaskType.OFFLINE)
           for i in range(4)]

engine.submit(online)
for r in offline:
    engine.submit(r)
stats = engine.run(max_iters=2000)

print(f"online tokens : {online.output_tokens}  (TTFT {online.ttft():.3f}s)")
for i, r in enumerate(offline):
    print(f"offline[{i}]    : {r.output_tokens}")
print(f"offline throughput : {stats.offline_throughput():.1f} tok/s (virtual)")
print(f"prefix cache hit   : {engine.bm.metrics.offline_hit_rate:.2%} "
      f"(doc prefix reused across the batch)")
