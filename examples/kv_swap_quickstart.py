"""KV tiering quickstart: the host swap tier on the virtual clock.

Runs the same bursty co-serving workload twice — recompute-only vs a
host-tier engine — and watches the swap traffic live through the service
event bus (``on_swap_in``/``on_swap_out``). Model-free (§5.4 simulator
methodology), so it runs in seconds on CPU.

    PYTHONPATH=src python examples/kv_swap_quickstart.py
"""
from repro.core import ECHO, SLO, EchoEngine, TimeModel
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.serving import EchoService


def workload(duration=30.0):
    trace = BurstyTrace(base_rate=2.0, burst_rate=10.0, burst_len=6.0,
                        burst_prob=0.1, tidal_period=4 * duration, seed=3)
    online = make_online_requests(trace.sample(0, duration), prompt_mean=128,
                                  prompt_std=32, max_new_mean=16,
                                  slo=SLO(1.0, 0.1), seed=1)
    offline = make_offline_corpus(8, 48, doc_len=256, question_len=24,
                                  max_new=8, seed=2)
    return online + offline


for host_blocks in (0, 256):
    eng = EchoEngine(None, None, ECHO, num_blocks=96, block_size=16,
                     chunk_size=64, time_model=TimeModel.a100(),
                     host_kv_blocks=host_blocks)
    service = EchoService(eng)
    first_swap = []
    service.events.on_swap_in(
        lambda ev: first_swap.append(ev) if not first_swap else None)
    stats = service.drive(workload(), max_iters=60_000, until_time=240.0)
    live = service.live
    label = f"host tier {host_blocks} blocks" if host_blocks else "recompute-only"
    print(f"--- {label} ---")
    print(f"  offline throughput : {stats.offline_throughput():.1f} tok/s")
    print(f"  SLO attainment     : TTFT {stats.slo_attainment('ttft'):.3f} "
          f"TPOT {stats.slo_attainment('tpot'):.3f}")
    print(f"  punished tokens    : {eng.bm.metrics.punished_tokens}")
    print(f"  swap traffic       : in {live.swapped_in_tokens} tok "
          f"({live.swap_ins} ev)  out {live.swapped_out_tokens} tok "
          f"({live.swap_outs} ev)")
    if first_swap:
        ev = first_swap[0]
        owner = f"rid={ev.handle.rid}" if ev.handle else "hash-level"
        print(f"  first swap-in      : {ev.tokens} tok at t={ev.t:.2f}s "
              f"({owner}) — prefix restored over PCIe, not recomputed")
