"""Observability quickstart: lifecycle tracing + metrics over a bursty
co-serve.

Attaches the full ``repro.obs`` layer — a ``Tracer`` (Chrome-trace ring
buffer), a ``MetricsRegistry`` (counters / gauges / pre-bucketed
histograms), and the estimator-drift probes — to an ``EchoService`` over a
virtual-clock engine with a host KV tier, then:

  * writes ``obs_trace.json``   — load it at https://ui.perfetto.dev: one
    track per request (queued / prefill chunks / decode / parked) plus
    schedule, kernel, and swap copy-stream tracks
  * writes ``obs_metrics.prom`` — Prometheus text exposition
  * prints the live p50/p90/p99 latency table and the drift-probe summary

Model-free (§5.4 simulator methodology), so it runs in seconds on CPU.

    PYTHONPATH=src python examples/observability_demo.py
"""
from repro.core import ECHO_C, SLO, EchoEngine, TimeModel
from repro.data import BurstyTrace, make_offline_corpus, make_online_requests
from repro.obs import MetricsRegistry, Tracer
from repro.obs.check import check_prometheus, check_trace
from repro.serving import EchoService


def workload(duration=30.0):
    trace = BurstyTrace(base_rate=2.0, burst_rate=10.0, burst_len=6.0,
                        burst_prob=0.1, tidal_period=4 * duration, seed=3)
    online = make_online_requests(trace.sample(0, duration), prompt_mean=128,
                                  prompt_std=32, max_new_mean=16,
                                  slo=SLO(1.0, 0.1), seed=1)
    offline = make_offline_corpus(8, 48, doc_len=256, question_len=24,
                                  max_new=8, seed=2)
    return online + offline


eng = EchoEngine(None, None, ECHO_C, num_blocks=96, block_size=16,
                 chunk_size=64, time_model=TimeModel.a100(),
                 host_kv_blocks=256)
service = EchoService(eng)

registry = MetricsRegistry()
tracer = Tracer(cap=100_000)
service.instrument(registry, tracer)

stats = service.drive(workload(), max_iters=60_000, until_time=240.0)

live = service.live
print(f"finished: {live.finished_online} online / "
      f"{live.finished_offline} offline  "
      f"preemptions {live.preemptions}  swaps in/out "
      f"{live.swap_ins}/{live.swap_outs}")
print("latency percentiles (s):")
for name, v in live.percentiles().items():
    print(f"  {name:>11}: p50 {v['p50']:.4f}  p90 {v['p90']:.4f}  "
          f"p99 {v['p99']:.4f}")

# drift probes: how well the scheduler's estimate tracked the clock
plan_err = registry.get("plan_rel_err").labels("0")
print(f"plan estimate rel err: mean "
      f"{plan_err.sum / max(plan_err.count, 1):.3f} over "
      f"{plan_err.count} iterations  "
      f"(calibrator refits: {eng.calibrator.refits})")

tracer.write("obs_trace.json")
registry.write("obs_metrics.prom")
print(f"trace: obs_trace.json {check_trace('obs_trace.json')} "
      f"({len(tracer.preempted_rids())} preempted / "
      f"{len(tracer.swapped_rids())} swapped requests) — "
      "load at https://ui.perfetto.dev")
print(f"metrics: obs_metrics.prom {check_prometheus('obs_metrics.prom')}")
